//! `hocs` — CLI for the Higher-order Count Sketch reproduction.
//!
//! ```text
//! hocs info                               # artifact / manifest summary
//! hocs train --model trl_mts_4x4x8 ...    # e2e training (Fig 10 curve)
//! hocs serve-demo [--backend xla]         # coordinator demo workload
//! hocs serve --addr HOST:PORT ...         # sharded sketch store server
//! hocs store-client <update|query|...>    # talk to a running store
//! hocs top --addr HOST:PORT               # live observability view (METRICS)
//! hocs bench <fig8|fig9|fig10|fig12|table1|table3|table45|table6|variance|service|all>
//! ```

use hocs::coordinator::{BackendKind, Coordinator, CoordinatorConfig, Job};
use hocs::experiments::{self, ExpConfig};
use hocs::rng::Pcg64;
use hocs::runtime::Runtime;
use hocs::store::{
    ClientOptions, StoreClient, StoreConfig, StoreServer, StoreServerConfig, TensorContraction,
    TensorFamily,
};
use hocs::util::cli::Args;

const USAGE: &str = "usage: hocs <info|train|serve-demo|serve|store-client|top|fault-crash|bench|lint> [options]\n\
\n\
  info                              artifact summary\n\
  train --model NAME [--steps N] [--lr F] [--eval-every N] [--seed N]\n\
  serve-demo [--backend xla|rust] [--requests N]\n\
  serve [--addr HOST:PORT] [--shards K] [--window N]\n\
        [--n1 N --n2 N --m1 M --m2 M --d D] [--store-seed S]\n\
        [--data-dir DIR] [--fsync] [--no-group-commit] [--with-coordinator]\n\
        [--peer ADDR[,ADDR…]] [--sync-interval-ms N] [--full-ship-every N]\n\
        [--replica-timeout-ms N]   (peers make this node a replica-cluster member)\n\
        [--read-timeout-ms N] [--max-connections N]   (overload guards; 0 = off)\n\
        (env: HOCS_TRACE=1 arms the span ring, HOCS_SLOW_US=N the slow-request log)\n\
  fault-crash --dir DIR [--ops N] [--start K] [--snapshot-at K] [--fsync]\n\
        [--seed S] [--peer ADDR] [--op-delay-us N]\n\
        (crash-harness child: scripted workload under HOCS_FAULTS failpoints)\n\
  store-client <update|update-batch|query|topk|heavy|stats|metrics|snapshot|advance-epoch|shutdown>\n\
        [--addr HOST:PORT] [--i I --j J --w W] [--k K] [--threshold T]\n\
        [--items \"i,j,w;i,j,w;…\"]   (update-batch: one group-commit frame)\n\
        [--timeout-ms N]   (connect + per-RPC timeout; 0 = wait forever)\n\
  store-client <tcreate|tupdate|tquery|marginal|slice-topk|contract>\n\
        --name T [--dims \"n1,n2,…\" --sketch-dims \"m1,m2,…\" --d D --seed S]\n\
        [--key \"i1,i2,…\" --w W] [--spec \"i,*,j\"]   (marginal: * sums a mode out)\n\
        [--mode M --index I --k K]   (slice-topk: dense scan of one slice)\n\
        [--other T2 --modes \"0,1,…\" [--dense]]   (contract: sketched contraction)\n\
  top [--addr HOST:PORT] [--interval-ms N] [--iterations N] [--once]\n\
        (live observability view scraped from METRICS: per-RPC qps/p50/p99,\n\
        WAL group sizes + fsync latency, scan cache, replication lag,\n\
        kernel dispatch, contraction accuracy)\n\
  bench <fig8|fig9|fig10|fig12|table1|table3|table45|table6|variance|service|ablation|all>\n\
        [--quick] [--seed N]\n\
  lint [--root DIR] [--deny] [--print-manifest]\n\
        (invariant checks: fault-coverage, opcode-symmetry, no-panic-paths,\n\
        version-gate; --deny exits 1 on findings, --print-manifest emits the\n\
        on-disk-format manifest for pinning after a FORMAT_VERSION bump)\n\
\n\
  global options: --artifacts DIR (AOT artifacts, default artifacts/),\n\
                  --debug (verbose logging)";

fn main() {
    let args = Args::from_env();
    if args.flag("debug") {
        hocs::util::logger::set_level(hocs::util::logger::Level::Debug);
    }
    let code = match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("train") => cmd_train(&args),
        Some("serve-demo") => cmd_serve_demo(&args),
        Some("serve") => cmd_serve(&args),
        Some("store-client") => cmd_store_client(&args),
        Some("top") => cmd_top(&args),
        Some("fault-crash") => cmd_fault_crash(&args),
        Some("bench") => cmd_bench(&args),
        Some("lint") => cmd_lint(&args),
        _ => {
            eprintln!("{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

fn artifacts_dir(args: &Args) -> String {
    args.get_str("artifacts", hocs::runtime::DEFAULT_ARTIFACTS_DIR)
}

fn cmd_info(args: &Args) -> i32 {
    let dir = artifacts_dir(args);
    let man = match hocs::runtime::Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    println!("artifacts: {dir}");
    println!("\nservice ops:");
    for (name, op) in &man.ops {
        println!(
            "  {name:<16} {} -> {:?}  ({} hash tables)",
            op.path, op.sketch_dims, op.hashes.len()
        );
    }
    println!("\nmodels:");
    for (name, m) in &man.models {
        println!(
            "  {name:<18} head={:<8} batch={} head_params={:<6} total={}",
            m.head, m.batch, m.head_param_count, m.total_param_count
        );
    }
    0
}

fn cmd_train(args: &Args) -> i32 {
    let dir = artifacts_dir(args);
    let model = args.get_str("model", "trl_mts_4x4x8");
    let steps = args.get_usize("steps", 400);
    let lr = args.get_f64("lr", 0.02) as f32;
    let seed = args.get_u64("seed", 42);
    let eval_every = args.get_usize("eval-every", 50);
    let rt = match Runtime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let mut tr = match hocs::train::Trainer::new(&rt, &model) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    match tr.train(steps, lr, eval_every, seed, false) {
        Ok(hist) => {
            let _ = std::fs::create_dir_all("results");
            let path = format!("results/train_{model}.json");
            let _ = std::fs::write(&path, hist.to_json().to_string_pretty());
            println!(
                "final test acc {:.3} ({} head params, {:.1}s) — history: {path}",
                hist.final_test_acc(),
                hist.head_param_count,
                hist.wall_secs
            );
            0
        }
        Err(e) => {
            eprintln!("training failed: {e}");
            1
        }
    }
}

fn cmd_serve_demo(args: &Args) -> i32 {
    let dir = artifacts_dir(args);
    let backend = match args.get_str("backend", "xla").as_str() {
        "rust" => BackendKind::PureRust,
        _ => BackendKind::Xla,
    };
    let n_req = args.get_usize("requests", 500);
    let co = match Coordinator::start(CoordinatorConfig {
        backend,
        artifacts_dir: dir.clone(),
        ..Default::default()
    }) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let man = hocs::runtime::Manifest::load(&dir).unwrap();
    let n = man.ops["cs_sketch"].input_dims[0];
    let mut rng = Pcg64::new(1);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for _ in 0..n_req {
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        match co.try_submit(Job::CsSketch(x)) {
            Ok(rx) => pending.push(rx),
            Err(_) => std::thread::sleep(std::time::Duration::from_micros(50)),
        }
    }
    for rx in pending {
        let _ = rx.recv();
    }
    println!(
        "{} requests in {:.2}s — {}",
        n_req,
        t0.elapsed().as_secs_f64(),
        co.metrics().summary()
    );
    co.shutdown();
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let store = StoreConfig {
        n1: args.get_usize("n1", 1 << 16),
        n2: args.get_usize("n2", 1 << 16),
        m1: args.get_usize("m1", 64),
        m2: args.get_usize("m2", 64),
        d: args.get_usize("d", 5),
        seed: args.get_u64("store-seed", 0x5EED),
        shards: args.get_usize("shards", 4),
        window: args.get_usize("window", 8),
    };
    // `--peer a:1,b:2` (or `--peers …`): comma-separated peer store
    // addresses; any peer makes this node a replica-cluster member
    let peers: Vec<String> = args
        .get("peer")
        .or_else(|| args.get("peers"))
        .map(|spec| {
            spec.split(',')
                .map(str::trim)
                .filter(|p| !p.is_empty())
                .map(str::to_string)
                .collect()
        })
        .unwrap_or_default();
    let cfg = StoreServerConfig {
        addr: args.get_str("addr", "127.0.0.1:7878"),
        store,
        data_dir: args.get("data-dir").map(str::to_string),
        fsync: args.flag("fsync"),
        // leader/follower cross-connection group commit is the default;
        // the flag restores per-record WAL commits (bench baseline /
        // debugging)
        group_commit: !args.flag("no-group-commit"),
        with_coordinator: args.flag("with-coordinator"),
        artifacts_dir: artifacts_dir(args),
        peers,
        sync_interval_ms: args.get_u64("sync-interval-ms", 100),
        full_ship_every: args.get_u64("full-ship-every", 0),
        replica_timeout_ms: args.get_u64("replica-timeout-ms", 2000),
        read_timeout_ms: args.get_u64("read-timeout-ms", 30_000),
        max_connections: args.get_u64("max-connections", 1024),
    };
    let n_peers = cfg.peers.len();
    // observability env toggles (flags would also work, but env keeps
    // them uniform with HOCS_KERNEL / HOCS_FAULTS)
    let trace_on = std::env::var("HOCS_TRACE")
        .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
        .unwrap_or(false);
    if trace_on {
        hocs::obs::trace::set_enabled(true);
    }
    if let Ok(v) = std::env::var("HOCS_SLOW_US") {
        if let Ok(us) = v.trim().parse::<u64>() {
            hocs::obs::trace::set_slow_threshold_us(us);
        }
    }
    match StoreServer::start(cfg) {
        Ok(server) => {
            let st = server.store().stats();
            println!(
                "store server on {} — {} shard(s), window {} epoch(s), {} peer(s); \
                 stop with `hocs store-client shutdown --addr {}`",
                server.local_addr(),
                st.shards,
                st.window,
                n_peers,
                server.local_addr()
            );
            server.wait();
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_store_client(args: &Args) -> i32 {
    let addr = args.get_str("addr", "127.0.0.1:7878");
    let action = args.positional.first().map(String::as_str).unwrap_or("stats");
    // bounded connect + per-RPC timeouts (0 = wait forever): a hung
    // server fails the CLI within the bound instead of stalling it
    let opts = ClientOptions::timeout_ms(args.get_u64("timeout-ms", 10_000));
    let mut client = match StoreClient::connect_with(&addr, opts) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let print_entries = |entries: &[(usize, usize, f64)]| {
        if entries.is_empty() {
            println!("(no keys)");
        }
        for (rank, (i, j, w)) in entries.iter().enumerate() {
            println!("{:>3}. ({i}, {j})  ~{w:.1}", rank + 1);
        }
    };
    let outcome = match action {
        "update" => {
            let (i, j) = (args.get_usize("i", 0), args.get_usize("j", 0));
            let w = args.get_f64("w", 1.0);
            client.update(i, j, w).map(|()| println!("ok: ({i}, {j}) += {w}"))
        }
        "update-batch" => {
            let spec = args.get_str("items", "");
            match parse_batch_items(&spec) {
                Ok(items) if !items.is_empty() => client
                    .update_batch(&items)
                    .map(|()| println!("ok: {} update(s) in one batch", items.len())),
                Ok(_) => {
                    eprintln!("update-batch needs --items \"i,j,w;i,j,w;…\"\n{USAGE}");
                    return 2;
                }
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            }
        }
        "query" => {
            let (i, j) = (args.get_usize("i", 0), args.get_usize("j", 0));
            client.query(i, j).map(|est| println!("estimate({i}, {j}) = {est}"))
        }
        "topk" => client.top_k(args.get_usize("k", 10)).map(|e| print_entries(&e)),
        "heavy" => {
            client.heavy_hitters(args.get_f64("threshold", 100.0)).map(|e| print_entries(&e))
        }
        "stats" => match client.stats_full() {
            Ok((s, repl)) => {
                println!(
                    "shards={} window={} epoch={} updates={}",
                    s.shards, s.window, s.epoch, s.updates
                );
                if let Some(r) = repl {
                    println!(
                        "replication: peers={} last_sync_age_ms={} cursor_version={} \
                         ships={} full_ships={} bytes_shipped={} merges_applied={} \
                         merges_deduped={}",
                        r.peers,
                        r.last_sync_age_ms.map_or_else(|| "never".to_string(), |a| a.to_string()),
                        r.cursor_version,
                        r.ships,
                        r.full_ships,
                        r.bytes_shipped,
                        r.merges_applied,
                        r.merges_deduped
                    );
                }
                // per-opcode request latency, best-effort (older servers
                // without the METRICS opcode just skip this block)
                if let Ok(text) = client.metrics() {
                    print_rpc_latency(&hocs::obs::expo::parse(&text));
                }
                Ok(())
            }
            Err(e) => Err(e),
        },
        "metrics" => client.metrics().map(|text| print!("{text}")),
        "snapshot" => client.snapshot().map(|()| println!("snapshot written")),
        "advance-epoch" => client.advance_epoch().map(|()| println!("epoch advanced")),
        "tcreate" => {
            let name = args.get_str("name", "t");
            let dims = match parse_index_list(&args.get_str("dims", "")) {
                Ok(d) if !d.is_empty() => d,
                Ok(_) => {
                    eprintln!("tcreate needs --dims \"n1,n2,…\"\n{USAGE}");
                    return 2;
                }
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            };
            let sketch_dims = match parse_index_list(&args.get_str("sketch-dims", "")) {
                Ok(m) if m.len() == dims.len() => m,
                Ok(_) => {
                    eprintln!("tcreate needs --sketch-dims with one entry per mode\n{USAGE}");
                    return 2;
                }
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            };
            let family = TensorFamily {
                dims,
                sketch_dims,
                d: args.get_usize("d", 5),
                seed: args.get_u64("seed", 0x5EED),
            };
            client.tensor_create(&name, &family).map(|created| {
                println!(
                    "{}: {name:?} {:?} -> {:?} (d={})",
                    if created { "created" } else { "already exists" },
                    family.dims,
                    family.sketch_dims,
                    family.d
                )
            })
        }
        "tupdate" => {
            let name = args.get_str("name", "t");
            match parse_index_list(&args.get_str("key", "")) {
                Ok(key) if !key.is_empty() => {
                    let w = args.get_f64("w", 1.0);
                    client.tensor_update(&name, &key, w).map(|()| {
                        println!("ok: {name:?}{key:?} += {w}");
                    })
                }
                Ok(_) => {
                    eprintln!("tupdate needs --key \"i1,i2,…\"\n{USAGE}");
                    return 2;
                }
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            }
        }
        "tquery" => {
            let name = args.get_str("name", "t");
            match parse_index_list(&args.get_str("key", "")) {
                Ok(key) if !key.is_empty() => client.tensor_query(&name, &key).map(|est| {
                    println!("estimate({name:?}, {key:?}) = {est}");
                }),
                Ok(_) => {
                    eprintln!("tquery needs --key \"i1,i2,…\"\n{USAGE}");
                    return 2;
                }
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            }
        }
        "marginal" => {
            let name = args.get_str("name", "t");
            let raw = args.get_str("spec", "");
            match parse_marginal_spec(&raw) {
                Ok(spec) if !spec.is_empty() => client.tensor_marginal(&name, &spec).map(|est| {
                    println!("marginal({name:?}, \"{raw}\") = {est}");
                }),
                Ok(_) => {
                    eprintln!("marginal needs --spec \"i,*,j\" (* sums a mode out)\n{USAGE}");
                    return 2;
                }
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            }
        }
        "slice-topk" => {
            let name = args.get_str("name", "t");
            let mode = args.get_usize("mode", 0);
            let index = args.get_usize("index", 0);
            let k = args.get_usize("k", 10);
            client.tensor_slice_topk(&name, mode, index, k).map(|entries| {
                if entries.is_empty() {
                    println!("(no keys)");
                }
                for (rank, (key, w)) in entries.iter().enumerate() {
                    println!("{:>3}. {key:?}  ~{w:.1}", rank + 1);
                }
            })
        }
        "contract" => {
            let name = args.get_str("name", "t");
            let other = args.get_str("other", "");
            if other.is_empty() {
                eprintln!("contract needs --other T2\n{USAGE}");
                return 2;
            }
            match parse_index_list(&args.get_str("modes", "")) {
                Ok(modes) if !modes.is_empty() => client
                    .tensor_contract(&name, &other, &modes, args.flag("dense"))
                    .map(|out| match out {
                        TensorContraction::Scalar(v) => println!("<{name:?}, {other:?}> = {v}"),
                        TensorContraction::Sketch(cs) => println!(
                            "contracted sketch: kept modes {:?}, dims {:?}, sketch {:?}, d={}",
                            cs.kept_modes, cs.kept_dims, cs.kept_sketch_dims, cs.d
                        ),
                        TensorContraction::Dense { dims, values } => {
                            println!("dense result {dims:?} ({} value(s)):", values.len());
                            for (i, v) in values.iter().enumerate().take(20) {
                                println!("  [{i}] {v}");
                            }
                            if values.len() > 20 {
                                println!("  … {} more", values.len() - 20);
                            }
                        }
                    }),
                Ok(_) => {
                    eprintln!("contract needs --modes \"0,1,…\"\n{USAGE}");
                    return 2;
                }
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            }
        }
        "shutdown" => client.shutdown_server().map(|()| println!("server stopping")),
        other => {
            eprintln!("unknown store-client action {other:?}\n{USAGE}");
            return 2;
        }
    };
    match outcome {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

/// One parsed exposition sample set; see [`hocs::obs::expo`].
type Samples = [hocs::obs::expo::Sample];

fn label_matches(s: &hocs::obs::expo::Sample, label: Option<(&str, &str)>) -> bool {
    match label {
        Some((k, v)) => s.label(k) == Some(v),
        None => true,
    }
}

/// First sample matching `name` (and, when given, a `key="val"` label).
fn metric(samples: &Samples, name: &str, label: Option<(&str, &str)>) -> Option<f64> {
    samples
        .iter()
        .find(|s| s.name == name && label_matches(s, label))
        .map(|s| s.value)
}

/// Cumulative `(le, count)` pairs of histogram `name` (the `_bucket`
/// suffix is appended here), filtered by an optional label.
fn hist_buckets(samples: &Samples, name: &str, label: Option<(&str, &str)>) -> Vec<(f64, f64)> {
    let bucket_name = format!("{name}_bucket");
    samples
        .iter()
        .filter(|s| s.name == bucket_name && label_matches(s, label))
        .map(|s| {
            let le = s.label("le").unwrap_or("0");
            let edge = if le == "+Inf" { f64::INFINITY } else { le.parse().unwrap_or(0.0) };
            (edge, s.value)
        })
        .collect()
}

/// Per-opcode request/latency lines shared by `store-client stats` and
/// `hocs top`: one line per opcode that has served traffic.
fn print_rpc_latency(samples: &Samples) {
    use hocs::obs::expo::percentile_from_buckets;
    for s in samples.iter().filter(|s| s.name == "hocs_rpc_requests_total" && s.value > 0.0) {
        let Some(op) = s.label("op") else { continue };
        let errors = metric(samples, "hocs_rpc_errors_total", Some(("op", op))).unwrap_or(0.0);
        let buckets = hist_buckets(samples, "hocs_rpc_latency_us", Some(("op", op)));
        if buckets.is_empty() {
            println!("rpc {op}: requests={} errors={errors}", s.value);
        } else {
            println!(
                "rpc {op}: requests={} errors={errors} p50={}us p99={}us",
                s.value,
                percentile_from_buckets(&buckets, 0.5),
                percentile_from_buckets(&buckets, 0.99)
            );
        }
    }
}

/// `hocs top` — poll the METRICS opcode and render a live view of the
/// whole observability plane; rates (qps) are first-differences between
/// consecutive scrapes.
fn cmd_top(args: &Args) -> i32 {
    let addr = args.get_str("addr", "127.0.0.1:7878");
    let once = args.flag("once");
    let interval_ms = args.get_u64("interval-ms", 1000).max(50);
    let iterations = args.get_usize("iterations", 0);
    let opts = ClientOptions::timeout_ms(args.get_u64("timeout-ms", 10_000));
    let mut client = match StoreClient::connect_with(&addr, opts) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let mut prev: Option<(std::time::Instant, Vec<hocs::obs::expo::Sample>)> = None;
    let mut rounds = 0usize;
    loop {
        let text = match client.metrics() {
            Ok(t) => t,
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        };
        let now = std::time::Instant::now();
        let samples = hocs::obs::expo::parse(&text);
        let rates = prev
            .as_ref()
            .map(|(t0, old)| (now.duration_since(*t0).as_secs_f64(), old.as_slice()));
        render_top(&addr, &samples, rates);
        rounds += 1;
        if once || (iterations > 0 && rounds >= iterations) {
            return 0;
        }
        prev = Some((now, samples));
        std::thread::sleep(std::time::Duration::from_millis(interval_ms));
    }
}

fn render_top(
    addr: &str,
    samples: &Samples,
    rates: Option<(f64, &Samples)>,
) {
    println!("--- hocs top @ {addr} ---");
    // per-RPC: qps needs two scrapes; the first round shows totals only
    for s in samples.iter().filter(|s| s.name == "hocs_rpc_requests_total" && s.value > 0.0) {
        let Some(op) = s.label("op") else { continue };
        let qps = rates
            .and_then(|(dt, old)| {
                let before = metric(old, "hocs_rpc_requests_total", Some(("op", op)))?;
                (dt > 0.0).then(|| (s.value - before).max(0.0) / dt)
            })
            .unwrap_or(0.0);
        let buckets = hist_buckets(samples, "hocs_rpc_latency_us", Some(("op", op)));
        println!(
            "rpc {op:<14} req={:<8} qps={qps:<8.1} p50={}us p99={}us",
            s.value,
            hocs::obs::expo::percentile_from_buckets(&buckets, 0.5),
            hocs::obs::expo::percentile_from_buckets(&buckets, 0.99)
        );
    }
    let g = |name: &str| metric(samples, name, None).unwrap_or(0.0);
    let fsync = hist_buckets(samples, "hocs_wal_fsync_us", None);
    let groups = hist_buckets(samples, "hocs_wal_group_frames", None);
    println!(
        "wal   appends={} bytes={} rotations={} fail_stops={} fsync_p99={}us \
         group_mean={:.1} group_max={}",
        g("hocs_wal_appends_total"),
        g("hocs_wal_bytes_total"),
        g("hocs_wal_rotations_total"),
        g("hocs_wal_fail_stops_total"),
        hocs::obs::expo::percentile_from_buckets(&fsync, 0.99),
        if g("hocs_wal_group_frames_count") > 0.0 {
            g("hocs_wal_group_frames_sum") / g("hocs_wal_group_frames_count")
        } else {
            0.0
        },
        hocs::obs::expo::percentile_from_buckets(&groups, 1.0),
    );
    println!(
        "scan  hits={} folds={} rebuilds={} hit_ratio={:.2}",
        g("hocs_scan_cache_hits_total"),
        g("hocs_scan_cache_folds_total"),
        g("hocs_scan_cache_rebuilds_total"),
        g("hocs_scan_cache_hit_ratio"),
    );
    println!(
        "kern  scalar={} portable={} avx2={}",
        metric(samples, "hocs_kernel_dispatch_total", Some(("path", "scalar"))).unwrap_or(0.0),
        metric(samples, "hocs_kernel_dispatch_total", Some(("path", "portable"))).unwrap_or(0.0),
        metric(samples, "hocs_kernel_dispatch_total", Some(("path", "avx2"))).unwrap_or(0.0),
    );
    println!(
        "repl  ticks={} settled={}",
        g("hocs_repl_ticks_total"),
        g("hocs_repl_settled_ticks_total")
    );
    for s in samples.iter().filter(|s| s.name == "hocs_repl_peer_synced") {
        let Some(peer) = s.label("peer") else { continue };
        let lag = metric(samples, "hocs_repl_peer_lag_ms", Some(("peer", peer)));
        println!(
            "peer  {peer}: synced={} lag_ms={} bytes={} ships={} full={}",
            s.value,
            lag.map_or_else(|| "-".to_string(), |l| format!("{l}")),
            metric(samples, "hocs_repl_peer_bytes_total", Some(("peer", peer))).unwrap_or(0.0),
            metric(samples, "hocs_repl_peer_ships_total", Some(("peer", peer))).unwrap_or(0.0),
            metric(samples, "hocs_repl_peer_full_ships_total", Some(("peer", peer)))
                .unwrap_or(0.0),
        );
    }
    if g("hocs_contracts_total") > 0.0 {
        println!("tensor contracts={}", g("hocs_contracts_total"));
        for s in samples.iter().filter(|s| s.name == "hocs_contract_ratio") {
            let Some(pair) = s.label("pair") else { continue };
            println!(
                "  {pair}: residual={:.4} bound={:.4} ratio={:.4}",
                metric(samples, "hocs_contract_residual", Some(("pair", pair))).unwrap_or(0.0),
                metric(samples, "hocs_contract_bound", Some(("pair", pair))).unwrap_or(0.0),
                s.value,
            );
        }
    }
    println!(
        "trace enabled={} spans={} dropped={} fault_injections={}",
        g("hocs_trace_enabled"),
        g("hocs_trace_spans_total"),
        g("hocs_trace_dropped_total"),
        g("hocs_fault_injections_total"),
    );
}

/// Crash-harness child mode: run a deterministic scripted workload against a
/// durable store, acknowledging each completed operation to `<dir>/acks.log`.
///
/// The parent test arms failpoints through the `HOCS_FAULTS` environment
/// variable, so this process may die (abort) or fail-stop (injected error) at
/// a chosen WAL/snapshot/replication site. On recovery the parent asserts
/// that the surviving state is an exact prefix of the workload at least as
/// long as the acknowledged prefix. `--start K` resumes the same workload at
/// op `K` (run 2 of a crash/recover/continue sequence); `--peer ADDR` ships
/// the stream to a receiver store and waits for the cursor to settle before
/// exiting cleanly.
fn cmd_fault_crash(args: &Args) -> i32 {
    use hocs::store::faults;
    use std::io::Write as _;
    faults::arm_from_env();
    let Some(dir) = args.get("dir") else {
        eprintln!("fault-crash needs --dir DIR\n{USAGE}");
        return 2;
    };
    let ops = args.get_usize("ops", 120);
    let start = args.get_usize("start", 0);
    let snapshot_at = args.get_usize("snapshot-at", 0);
    let seed = args.get_u64("seed", 77);
    let op_delay_us = args.get_u64("op-delay-us", 0);
    let cfg = faults::crash_config();
    let opts = hocs::store::DurableOptions { fsync: args.flag("fsync"), group_commit: true };
    let store = match hocs::store::DurableStore::open_opts(std::path::Path::new(dir), cfg, opts) {
        Ok(s) => std::sync::Arc::new(s),
        Err(e) => {
            eprintln!("fault-crash: open failed: {e}");
            return 1;
        }
    };
    let cfg = store.config().clone();
    let mut _replicator = None;
    let mut counters = None;
    if let Some(peer) = args.get("peer") {
        store.enable_replication();
        let c = std::sync::Arc::new(hocs::store::replica::ReplicationCounters::new(1));
        let rcfg = hocs::store::ReplicaConfig {
            peers: vec![peer.to_string()],
            sync_interval_ms: 10,
            ..Default::default()
        };
        match hocs::store::Replicator::start(store.clone(), rcfg, c.clone()) {
            Ok(r) => {
                _replicator = Some(r);
                counters = Some(c);
            }
            Err(e) => {
                eprintln!("fault-crash: replicator failed: {e}");
                return 1;
            }
        }
    }
    let ack_path = std::path::Path::new(dir).join("acks.log");
    let mut ack = match std::fs::OpenOptions::new().create(true).append(true).open(&ack_path) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("fault-crash: cannot open {}: {e}", ack_path.display());
            return 1;
        }
    };
    let workload = faults::crash_workload(&cfg, start + ops, seed);
    for (k, op) in workload.iter().enumerate().skip(start).take(ops) {
        if snapshot_at > 0 && k == snapshot_at {
            if let Err(e) = store.snapshot() {
                eprintln!("fault-crash: snapshot failed at op {k}: {e}");
                return 3;
            }
        }
        if let Err(e) = faults::apply_crash_op(&store, &cfg, op) {
            eprintln!("fault-crash: op {k} failed: {e}");
            return 3;
        }
        // an op is "acknowledged" only once its WAL frame is flushed — the
        // line below is the durability contract the parent test checks
        if writeln!(ack, "{k}").and_then(|()| ack.flush()).is_err() {
            eprintln!("fault-crash: ack log write failed");
            return 1;
        }
        if op_delay_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(op_delay_us));
        }
    }
    if let Some(c) = counters {
        // wait (bounded) for the replicator's durable cursor to catch the
        // local origin version so a clean exit implies a converged peer
        let target = store.origin_version();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        while c.snapshot().cursor_version < target {
            if std::time::Instant::now() >= deadline {
                eprintln!("fault-crash: replication did not settle (target version {target})");
                return 4;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        // then wait for one settled tick that began after the cursor
        // caught up: the replicator's settled predicate covers the
        // tensor plane too, so a fresh settle implies every tensor ship
        // is acked (the cursor version alone only tracks the 2-D plane).
        // The small epsilon absorbs last_sync_age_ms rounding.
        let reached = std::time::Instant::now() + std::time::Duration::from_millis(5);
        loop {
            let settled_at = c
                .snapshot()
                .last_sync_age_ms
                .map(|age| std::time::Instant::now() - std::time::Duration::from_millis(age));
            if settled_at.is_some_and(|t| t >= reached) {
                break;
            }
            if std::time::Instant::now() >= deadline {
                eprintln!("fault-crash: tensor replication did not settle");
                return 4;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
    }
    let live = store.stats().updates;
    println!("fault-crash: ops [{start}, {}) done — {live} updates live", start + ops);
    0
}

/// Parse a comma-separated index list like `"20,16,12"` (tensor dims,
/// multi-mode keys, contraction mode ids).
fn parse_index_list(spec: &str) -> Result<Vec<usize>, String> {
    spec.split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(|p| p.parse::<usize>().map_err(|_| format!("bad index {p:?} in {spec:?}")))
        .collect()
}

/// Parse a marginal spec like `"3,*,1"`: a `*` sums that mode out.
fn parse_marginal_spec(spec: &str) -> Result<Vec<Option<usize>>, String> {
    spec.split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(|p| {
            if p == "*" {
                Ok(None)
            } else {
                p.parse::<usize>()
                    .map(Some)
                    .map_err(|_| format!("bad index {p:?} in {spec:?} (use * to sum a mode out)"))
            }
        })
        .collect()
}

/// Parse `"i,j,w;i,j,w;…"` into update triples for the batched RPC.
fn parse_batch_items(spec: &str) -> Result<Vec<(u32, u32, f64)>, String> {
    let mut items = Vec::new();
    for chunk in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
        let parts: Vec<&str> = chunk.split(',').map(str::trim).collect();
        if parts.len() != 3 {
            return Err(format!("batch item {chunk:?} is not \"i,j,w\""));
        }
        let i: u32 = parts[0].parse().map_err(|_| format!("bad row key in {chunk:?}"))?;
        let j: u32 = parts[1].parse().map_err(|_| format!("bad col key in {chunk:?}"))?;
        let w: f64 = parts[2].parse().map_err(|_| format!("bad weight in {chunk:?}"))?;
        items.push((i, j, w));
    }
    Ok(items)
}

fn cmd_bench(args: &Args) -> i32 {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let cfg = ExpConfig { quick: args.flag("quick"), seed: args.get_u64("seed", 20190711) };
    let dir = artifacts_dir(args);
    let needs_rt = matches!(which, "fig10" | "fig12" | "all");
    let rt = if needs_rt {
        match Runtime::new(&dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("warning: artifacts unavailable ({e}); skipping fig10/fig12");
                None
            }
        }
    } else {
        None
    };

    let mut run = |name: &str| -> i32 {
        match name {
            "fig8" => experiments::run_fig8(&cfg, 10).0.print(),
            "fig9" => experiments::run_fig9(&cfg).0.print(),
            "table1" => experiments::run_table1(&cfg).print(),
            "table3" => experiments::run_table3(&cfg, &[8, 12, 16, 24, 32]).0.print(),
            "table45" => experiments::run_table45(
                &cfg,
                &[(12, 2), (12, 4), (16, 6), (8, 10), (6, 12)],
            )
            .0
            .print(),
            "table6" => {
                experiments::run_table6(&cfg, &[(12, 2), (16, 4), (16, 8), (8, 12)]).0.print()
            }
            "variance" => experiments::run_variance(&cfg).0.print(),
            "ablation" => {
                experiments::run_ablation_sketch_path(&cfg).print();
                println!();
                experiments::run_ablation_fft_packing(&cfg).print();
                println!();
                experiments::run_ablation_median_d(&cfg).print();
                println!();
                match experiments::run_ablation_batching(&cfg, &dir) {
                    Ok(t) => t.print(),
                    Err(e) => eprintln!("batching ablation skipped: {e}"),
                }
            }
            "service" => match experiments::run_service_bench(&cfg, &dir) {
                Ok((t, _)) => t.print(),
                Err(e) => {
                    eprintln!("service bench failed: {e}");
                    return 1;
                }
            },
            "fig10" => {
                if let Some(rt) = rt.as_ref() {
                    match experiments::run_fig10(&cfg, rt) {
                        Ok((t, _)) => t.print(),
                        Err(e) => {
                            eprintln!("fig10 failed: {e}");
                            return 1;
                        }
                    }
                }
            }
            "fig12" => {
                if let Some(rt) = rt.as_ref() {
                    match experiments::run_fig12(&cfg, rt) {
                        Ok((t, _)) => t.print(),
                        Err(e) => {
                            eprintln!("fig12 failed: {e}");
                            return 1;
                        }
                    }
                }
            }
            other => {
                eprintln!("unknown bench {other:?}");
                return 2;
            }
        }
        0
    };

    if which == "all" {
        for name in [
            "fig8", "fig9", "table1", "table3", "table45", "table6", "variance", "service",
            "ablation", "fig10", "fig12",
        ] {
            println!();
            let rc = run(name);
            if rc != 0 {
                return rc;
            }
        }
        0
    } else {
        run(which)
    }
}

fn cmd_lint(args: &Args) -> i32 {
    let root = args.get_str("root", "rust/src");
    let root = std::path::Path::new(&root);
    if args.flag("print-manifest") {
        let wal = root.join("store").join("wal.rs");
        let raw = match std::fs::read_to_string(&wal) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("error: reading {}: {e}", wal.display());
                return 1;
            }
        };
        return match hocs::analysis::version_gate::extract_manifest(&raw) {
            Ok((manifest, _version)) => {
                print!("{manifest}");
                0
            }
            Err(e) => {
                eprintln!("error: {e}");
                1
            }
        };
    }
    let violations = match hocs::analysis::run_lint(root) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    for v in &violations {
        println!("{v}");
    }
    if violations.is_empty() {
        eprintln!("lint: clean");
        0
    } else {
        eprintln!("lint: {} violation(s)", violations.len());
        if args.flag("deny") {
            1
        } else {
            0
        }
    }
}
