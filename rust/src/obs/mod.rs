//! Unified observability plane: metrics registry, tracing spans, and
//! the exposition surface behind the `METRICS` opcode / `hocs top`.
//!
//! Three layers, in dependency order:
//!
//! - [`registry`] — the process-global lock-free metric store:
//!   [`registry::Counter`], [`registry::Gauge`], and the log2
//!   [`registry::Histo`] (the PR-1 coordinator latency histogram,
//!   generalized; `coordinator/metrics.rs` now embeds one). All hot
//!   recording paths are statically-registered slots — counters cost
//!   one relaxed `fetch_add`; histograms three adds and a
//!   `fetch_max`. Dynamic families (per-peer replication, per-pair
//!   contraction accuracy) take a mutex only at registration and
//!   render time, never per sample.
//! - [`trace`] — per-thread ring-buffer span log. `span!("name")`
//!   opens an RAII guard stamping monotonic durations into the
//!   calling thread's 1024-record ring (oldest overwritten on
//!   overflow, drops counted). Disabled by default: a disabled span
//!   is one relaxed load at open and nothing at drop. A
//!   threshold-gated slow-request log rides alongside.
//! - [`expo`] — Prometheus-style text rendering and a tolerant
//!   parser, shared by the server (render) and `hocs top` /
//!   `store-client stats` (parse).
//!
//! ## Overhead contract
//!
//! Instrumentation must be invisible at serving granularity: per-RPC
//! cost is one `Instant` read pair + one histogram record; WAL and
//! scan-cache sites add one counter each; kernel dispatch counts per
//! tile/batch, not per element. With the tracing ring **disabled**
//! (default) added cost is ~0; with it **enabled**, `bench_store`'s
//! `obs` section measures the full update path and CI holds the
//! regression at ≤ 3%.
//!
//! ## Metric catalog
//!
//! | family | type | labels | meaning |
//! |---|---|---|---|
//! | `hocs_rpc_requests_total` | counter | `op` | requests served, per opcode |
//! | `hocs_rpc_errors_total` | counter | `op` | `STATUS_ERR` responses, per opcode |
//! | `hocs_rpc_latency_us` | histogram | `op` | end-to-end request latency |
//! | `hocs_wal_appends_total` | counter | | durable WAL writes (group = 1) |
//! | `hocs_wal_bytes_total` | counter | | framed bytes appended |
//! | `hocs_wal_fsync_us` | histogram | | `sync_data` latency per append |
//! | `hocs_wal_group_frames` | histogram | | frames coalesced per leader write |
//! | `hocs_wal_rotations_total` | counter | | snapshot + WAL rotations |
//! | `hocs_wal_fail_stops_total` | counter | | WAL fail-stop transitions |
//! | `hocs_scan_cache_hits_total` | counter | | scans served from a current stamp |
//! | `hocs_scan_cache_folds_total` | counter | | incremental delta folds |
//! | `hocs_scan_cache_rebuilds_total` | counter | | full K-way re-merges |
//! | `hocs_scan_cache_hit_ratio` | gauge | | hits / (hits+folds+rebuilds) |
//! | `hocs_kernel_dispatch_total` | counter | `path` | scalar / portable / avx2 dispatches |
//! | `hocs_fault_injections_total` | counter | | armed fault-plane firings |
//! | `hocs_repl_ticks_total` | counter | | replicator loop ticks |
//! | `hocs_repl_settled_ticks_total` | counter | | ticks with all peers settled |
//! | `hocs_repl_peer_synced` | gauge | `peer` | 1 once the channel ever settled |
//! | `hocs_repl_peer_lag_ms` | gauge | `peer` | now − last settled tick |
//! | `hocs_repl_peer_bytes_total` | counter | `peer` | replication bytes shipped |
//! | `hocs_repl_peer_ships_total` | counter | `peer` | delta frames shipped |
//! | `hocs_repl_peer_full_ships_total` | counter | `peer` | full-state frames shipped |
//! | `hocs_contracts_total` | counter | | CONTRACT RPCs measured |
//! | `hocs_contract_residual` | gauge | `pair` | observed per-repeat estimator spread |
//! | `hocs_contract_bound` | gauge | `pair` | theoretical `8·‖A‖‖B‖/√Πm` |
//! | `hocs_contract_ratio` | gauge | `pair` | residual / bound (healthy ≪ 1) |
//! | `hocs_trace_enabled` | gauge | | tracing ring armed? |
//! | `hocs_trace_spans_total` | counter | | spans recorded |
//! | `hocs_trace_dropped_total` | counter | | ring overwrites |
//!
//! Metric names are a compatibility surface: the exposition golden
//! test in `rust/tests/obs.rs` and the CI `obs-smoke` schema check
//! both pin them.

pub mod expo;
pub mod registry;
pub mod trace;

pub use registry::{global, now_ms, Counter, Gauge, Histo, Registry};

/// Render the full exposition payload served by the `METRICS` opcode:
/// the global registry, tracing-layer gauges, and any retained
/// slow-request lines (as `# slow:` comments, so parsers skip them).
/// Panic-free: this runs on a served route.
pub fn render_text() -> String {
    let mut out = String::with_capacity(4096);
    global().render_into(&mut out);
    expo::render_sample(
        &mut out,
        "hocs_trace_enabled",
        &[],
        if trace::enabled() { 1.0 } else { 0.0 },
    );
    expo::render_sample(&mut out, "hocs_trace_spans_total", &[], trace::spans_total() as f64);
    expo::render_sample(&mut out, "hocs_trace_dropped_total", &[], trace::dropped_total() as f64);
    for line in trace::drain_slow() {
        let clean: String = line.chars().map(|c| if c == '\n' { ' ' } else { c }).collect();
        out.push_str("# slow: ");
        out.push_str(&clean);
        out.push('\n');
    }
    out
}
