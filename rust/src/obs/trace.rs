//! Low-overhead tracing: per-thread ring-buffer span log + slow-request log.
//!
//! A [`span`] guard stamps a monotonic start time on construction and
//! records `(name, start, duration, thread)` into the calling thread's
//! ring on drop. When tracing is disabled (the default) the guard
//! holds `None` and both ends cost one relaxed atomic load — no clock
//! read, no ring touch, no allocation. Rings are fixed-size (
//! [`RING_CAP`] records) and overwrite oldest-first on overflow,
//! counting what they dropped; they are registered once per thread in
//! a global table and drained on demand by [`drain`] (exposition,
//! `hocs top`) without stopping writers.
//!
//! The slow-request log is orthogonal: when a threshold is armed via
//! [`set_slow_threshold_us`], the server loop calls [`note_slow`] for
//! any request over it, into a bounded deque drained alongside
//! METRICS output.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Records per thread ring. 1024 × 32 B keeps a busy thread's recent
/// ~millisecond history without measurable cache pressure.
pub const RING_CAP: usize = 1024;

/// Cap on retained slow-request lines.
pub const SLOW_LOG_CAP: usize = 64;

/// One completed span.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanRec {
    pub name: &'static str,
    /// start, monotonic ms since process obs epoch (see
    /// [`super::registry::now_ms`])
    pub start_ms: u64,
    pub dur_us: u64,
    /// recording thread, as `thread::current().id()` debug text
    /// (shared — formatted once per thread, refcounted per record)
    pub thread: Arc<str>,
}

#[derive(Debug, Default)]
struct RingInner {
    buf: Vec<SpanRec>,
    /// next write position once `buf` is full (wraparound overwrite)
    next: usize,
    dropped: u64,
}

#[derive(Debug, Default)]
struct Ring {
    inner: Mutex<RingInner>,
}

impl Ring {
    /// Returns `true` when the push overwrote (dropped) an old record.
    fn push(&self, rec: SpanRec) -> bool {
        let mut st = match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if st.buf.len() < RING_CAP {
            st.buf.push(rec);
            false
        } else {
            let at = st.next;
            if let Some(slot) = st.buf.get_mut(at) {
                *slot = rec;
            }
            st.next = (at + 1) % RING_CAP;
            st.dropped += 1;
            true
        }
    }

    /// Oldest-first snapshot plus the overwrite count, leaving the
    /// ring empty.
    fn drain(&self) -> (Vec<SpanRec>, u64) {
        let mut st = match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let split = st.next.min(st.buf.len());
        let mut out: Vec<SpanRec> = st.buf.get(split..).map(|s| s.to_vec()).unwrap_or_default();
        out.extend(st.buf.get(..split).map(|s| s.to_vec()).unwrap_or_default());
        let dropped = st.dropped;
        st.buf.clear();
        st.next = 0;
        st.dropped = 0;
        (out, dropped)
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static SPANS_TOTAL: AtomicU64 = AtomicU64::new(0);
static DROPPED_TOTAL: AtomicU64 = AtomicU64::new(0);
static SLOW_THRESHOLD_US: AtomicU64 = AtomicU64::new(0);

fn rings() -> &'static Mutex<Vec<Arc<Ring>>> {
    static RINGS: OnceLock<Mutex<Vec<Arc<Ring>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

fn slow_log() -> &'static Mutex<std::collections::VecDeque<String>> {
    static LOG: OnceLock<Mutex<std::collections::VecDeque<String>>> = OnceLock::new();
    LOG.get_or_init(|| Mutex::new(std::collections::VecDeque::new()))
}

thread_local! {
    static THREAD_RING: Arc<Ring> = {
        let ring = Arc::new(Ring::default());
        if let Ok(mut table) = rings().lock() {
            table.push(ring.clone());
        }
        ring
    };

    /// Thread id debug text, formatted once — span drops must not
    /// allocate (the ≤3% instrumentation-overhead contract).
    static THREAD_LABEL: Arc<str> = format!("{:?}", std::thread::current().id()).into();
}

/// Turn span recording on/off process-wide. Off is the default and
/// makes every [`span`] guard a near-no-op.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Total spans recorded since process start (across all threads).
pub fn spans_total() -> u64 {
    SPANS_TOTAL.load(Ordering::Relaxed)
}

/// Total ring overwrites (recorded spans that were evicted unread).
pub fn dropped_total() -> u64 {
    DROPPED_TOTAL.load(Ordering::Relaxed)
}

/// RAII span guard: created by [`span`] / the `span!` macro, records
/// its duration into the thread ring on drop.
#[derive(Debug)]
pub struct Span {
    name: &'static str,
    /// `None` when tracing was disabled at construction — drop is a
    /// no-op then
    start: Option<Instant>,
    start_ms: u64,
}

impl Span {
    /// Duration so far, µs (0 when tracing is disabled).
    pub fn elapsed_us(&self) -> u64 {
        self.start.map(|t| t.elapsed().as_micros() as u64).unwrap_or(0)
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let rec = SpanRec {
            name: self.name,
            start_ms: self.start_ms,
            dur_us: start.elapsed().as_micros() as u64,
            thread: THREAD_LABEL.with(Arc::clone),
        };
        SPANS_TOTAL.fetch_add(1, Ordering::Relaxed);
        THREAD_RING.with(|ring| {
            if ring.push(rec) {
                DROPPED_TOTAL.fetch_add(1, Ordering::Relaxed);
            }
        });
    }
}

/// Open a span. One relaxed load when tracing is off; `name` must be
/// a static literal (dot-separated convention: `"wal.group_commit"`).
pub fn span(name: &'static str) -> Span {
    if !ENABLED.load(Ordering::Relaxed) {
        return Span { name, start: None, start_ms: 0 };
    }
    Span { name, start: Some(Instant::now()), start_ms: super::registry::now_ms() }
}

/// `span!("wal.group_commit")` — sugar for [`span`] that binds the
/// guard to a hidden local so it lives to end of scope.
#[macro_export]
macro_rules! span {
    ($name:literal) => {
        let _obs_span = $crate::obs::trace::span($name);
    };
}

/// Drain every thread's ring: oldest-first per thread, rings left
/// empty. Returns all records plus the total overwrite count since
/// the last drain.
pub fn drain() -> (Vec<SpanRec>, u64) {
    let table: Vec<Arc<Ring>> = match rings().lock() {
        Ok(g) => g.clone(),
        Err(p) => p.into_inner().clone(),
    };
    let mut out = Vec::new();
    let mut dropped = 0;
    for ring in table {
        let (mut recs, d) = ring.drain();
        out.append(&mut recs);
        dropped += d;
    }
    (out, dropped)
}

/// Drain only the calling thread's ring (deterministic for tests).
pub fn drain_current() -> (Vec<SpanRec>, u64) {
    THREAD_RING.with(|ring| ring.drain())
}

/// Arm (µs > 0) or disarm (0) the slow-request log.
pub fn set_slow_threshold_us(us: u64) {
    SLOW_THRESHOLD_US.store(us, Ordering::Relaxed);
}

pub fn slow_threshold_us() -> u64 {
    SLOW_THRESHOLD_US.load(Ordering::Relaxed)
}

/// Append one line to the slow-request log (oldest evicted past
/// [`SLOW_LOG_CAP`]). Callers check [`slow_threshold_us`] first so
/// the common case never formats anything.
pub fn note_slow(line: String) {
    let mut log = match slow_log().lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    if log.len() >= SLOW_LOG_CAP {
        log.pop_front();
    }
    log.push_back(line);
}

/// Take every retained slow-request line.
pub fn drain_slow() -> Vec<String> {
    let mut log = match slow_log().lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    log.drain(..).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_span_records_nothing() {
        set_enabled(false);
        drain_current();
        {
            let s = span("test.noop");
            assert_eq!(s.elapsed_us(), 0);
        }
        let (recs, _) = drain_current();
        assert!(recs.is_empty());
    }

    #[test]
    fn slow_log_is_bounded() {
        drain_slow();
        for i in 0..(SLOW_LOG_CAP + 10) {
            note_slow(format!("req {i}"));
        }
        let lines = drain_slow();
        assert_eq!(lines.len(), SLOW_LOG_CAP);
        assert_eq!(lines.first().map(String::as_str), Some("req 10"));
    }
}
