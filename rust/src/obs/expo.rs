//! Prometheus-style text exposition: render helpers used by
//! [`super::registry::Registry::render_into`] and a tolerant parser
//! used by `hocs top`, `store-client stats`, and the round-trip tests.
//!
//! Format subset: `name{label="value",...} number` lines plus `#`
//! comments. Histograms follow the Prometheus convention — cumulative
//! `_bucket{le="..."}` series ending in `le="+Inf"`, plus `_sum`,
//! `_count`, and a non-standard `_max` gauge (the registry tracks
//! exact maxima for free). Trailing empty buckets are trimmed; the
//! `le` edges are the log2 bucket upper bounds `2^i`.

use super::registry::Histo;

/// One parsed exposition line.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

impl Sample {
    /// Label value by key, if present.
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

fn escape_label(out: &mut String, v: &str) {
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
}

fn render_name(out: &mut String, name: &str, labels: &[(&str, &str)]) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(k);
            out.push_str("=\"");
            escape_label(out, v);
            out.push('"');
        }
        out.push('}');
    }
}

/// Append one `name{labels} value` line.
pub fn render_sample(out: &mut String, name: &str, labels: &[(&str, &str)], value: f64) {
    render_name(out, name, labels);
    out.push(' ');
    if value.fract() == 0.0 && value.abs() < 1e15 {
        out.push_str(&format!("{}", value as i64));
    } else {
        out.push_str(&format!("{value}"));
    }
    out.push('\n');
}

/// Append a full histogram family: cumulative `_bucket` lines (log2
/// upper edges, trailing empties trimmed), `+Inf`, `_sum`, `_count`,
/// `_max`.
pub fn render_histogram(out: &mut String, name: &str, labels: &[(&str, &str)], h: &Histo) {
    let counts = h.bucket_counts();
    let last_nonzero = counts.iter().rposition(|&c| c > 0);
    let mut cum = 0u64;
    if let Some(last) = last_nonzero {
        for (i, &c) in counts.iter().enumerate().take(last + 1) {
            cum += c;
            let mut lbls: Vec<(&str, &str)> = labels.to_vec();
            let le = format!("{}", 1u64 << i.min(63));
            lbls.push(("le", le.as_str()));
            render_sample(out, &format!("{name}_bucket"), &lbls, cum as f64);
        }
    }
    let mut lbls: Vec<(&str, &str)> = labels.to_vec();
    lbls.push(("le", "+Inf"));
    render_sample(out, &format!("{name}_bucket"), &lbls, h.count() as f64);
    render_sample(out, &format!("{name}_sum"), labels, h.sum() as f64);
    render_sample(out, &format!("{name}_count"), labels, h.count() as f64);
    render_sample(out, &format!("{name}_max"), labels, h.max() as f64);
}

/// Parse exposition text back into samples. Tolerant: `#` comments,
/// blank lines, and malformed lines are skipped, never an error —
/// `hocs top` must keep rendering even if a scrape is torn mid-line.
pub fn parse(text: &str) -> Vec<Sample> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(s) = parse_line(line) {
            out.push(s);
        }
    }
    out
}

fn parse_line(line: &str) -> Option<Sample> {
    let (head, value_str) = match line.find('}') {
        Some(close) => {
            let (h, rest) = line.split_at(close + 1);
            (h, rest.trim())
        }
        None => {
            let mut it = line.split_whitespace();
            let h = it.next()?;
            (h, it.next()?)
        }
    };
    let value: f64 = value_str.split_whitespace().next()?.parse().ok()?;
    let (name, labels) = match head.find('{') {
        Some(open) => {
            let name = head.get(..open)?;
            let body = head.get(open + 1..head.len().saturating_sub(1))?;
            (name, parse_labels(body)?)
        }
        None => (head, Vec::new()),
    };
    if name.is_empty() {
        return None;
    }
    Some(Sample { name: name.to_string(), labels, value })
}

fn parse_labels(body: &str) -> Option<Vec<(String, String)>> {
    let mut out = Vec::new();
    let mut rest = body.trim();
    while !rest.is_empty() {
        let eq = rest.find('=')?;
        let key = rest.get(..eq)?.trim().to_string();
        rest = rest.get(eq + 1..)?.trim_start();
        rest = rest.strip_prefix('"')?;
        // scan to the closing quote, honoring backslash escapes
        let mut val = String::new();
        let mut chars = rest.char_indices();
        let mut end = None;
        loop {
            let Some((i, c)) = chars.next() else { break };
            match c {
                '\\' => {
                    if let Some((_, e)) = chars.next() {
                        match e {
                            'n' => val.push('\n'),
                            other => val.push(other),
                        }
                    }
                }
                '"' => {
                    end = Some(i);
                    break;
                }
                other => val.push(other),
            }
        }
        let end = end?;
        out.push((key, val));
        rest = rest.get(end + 1..)?.trim_start();
        rest = rest.strip_prefix(',').unwrap_or(rest).trim_start();
    }
    Some(out)
}

/// Percentile from parsed cumulative histogram buckets
/// (`(le, cumulative_count)`, any order; `+Inf` may be `f64::INFINITY`).
/// Returns the smallest finite `le` covering the p-quantile, falling
/// back to the largest finite edge.
pub fn percentile_from_buckets(buckets: &[(f64, f64)], p: f64) -> f64 {
    let mut sorted: Vec<(f64, f64)> = buckets.to_vec();
    sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
    let total = sorted.iter().map(|&(_, c)| c).fold(0.0f64, f64::max);
    if total <= 0.0 {
        return 0.0;
    }
    let target = (total * p.clamp(0.0, 1.0)).ceil().max(1.0);
    let mut best_finite = 0.0;
    for &(le, cum) in &sorted {
        if le.is_finite() {
            best_finite = le;
        }
        if cum >= target && le.is_finite() {
            return le;
        }
    }
    best_finite
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_round_trip() {
        let mut text = String::new();
        render_sample(&mut text, "hocs_rpc_requests_total", &[("op", "UPDATE")], 42.0);
        render_sample(&mut text, "hocs_scan_cache_hit_ratio", &[], 0.75);
        let samples = parse(&text);
        assert_eq!(samples.len(), 2);
        assert_eq!(samples[0].name, "hocs_rpc_requests_total");
        assert_eq!(samples[0].label("op"), Some("UPDATE"));
        assert_eq!(samples[0].value, 42.0);
        assert_eq!(samples[1].value, 0.75);
    }

    #[test]
    fn label_escaping_round_trips() {
        let mut text = String::new();
        render_sample(&mut text, "m", &[("k", "a\"b\\c")], 1.0);
        let samples = parse(&text);
        assert_eq!(samples[0].label("k"), Some("a\"b\\c"));
    }

    #[test]
    fn histogram_renders_cumulative_and_parses() {
        let h = Histo::new();
        for v in [1u64, 3, 3, 100] {
            h.record(v);
        }
        let mut text = String::new();
        render_histogram(&mut text, "lat_us", &[("op", "Q")], &h);
        let samples = parse(&text);
        let inf = samples
            .iter()
            .find(|s| s.name == "lat_us_bucket" && s.label("le") == Some("+Inf"))
            .expect("+Inf bucket");
        assert_eq!(inf.value, 4.0);
        let sum = samples.iter().find(|s| s.name == "lat_us_sum").expect("sum");
        assert_eq!(sum.value, 107.0);
        // cumulative counts never decrease
        let mut last = 0.0;
        for s in samples.iter().filter(|s| s.name == "lat_us_bucket") {
            assert!(s.value >= last);
            last = s.value;
        }
    }

    #[test]
    fn percentile_from_parsed_buckets() {
        let buckets =
            vec![(2.0, 10.0), (4.0, 90.0), (8.0, 99.0), (16.0, 100.0), (f64::INFINITY, 100.0)];
        assert_eq!(percentile_from_buckets(&buckets, 0.5), 4.0);
        assert_eq!(percentile_from_buckets(&buckets, 0.99), 8.0);
        assert_eq!(percentile_from_buckets(&buckets, 1.0), 16.0);
    }
}
