//! The lock-free metrics registry: counters, gauges, log2 histograms,
//! and the process-global [`Registry`] every subsystem records into.
//!
//! Hot-path contract: recording is one (histograms: three) `Relaxed`
//! atomic adds on statically-registered slots — no locks, no
//! allocation, no branches beyond the bucket index. Dynamic families
//! (per-peer replication channels, per-pair contraction accuracy) hand
//! out `Arc` slots from a mutex-guarded table that is locked only at
//! registration and exposition time, never per sample.
//!
//! Exposition ([`Registry::render_into`]) is read-only, panic-free
//! (it runs on a served route — the `no-panic-paths` lint scopes it),
//! and tolerant of torn reads: counters are statistics, not
//! synchronization, so a sample raced mid-render is off by one, not
//! wrong.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Number of power-of-two histogram buckets (bucket `i` covers
/// `[2^(i-1), 2^i)`; bucket 0 is `< 1`). 32 buckets reach ~35 min in
/// µs units, ~4 × 10⁹ in dimensionless units (group sizes).
pub const HIST_BUCKETS: usize = 32;

/// Highest opcode the per-RPC table holds slots for (inclusive). Kept
/// a power-of-two headroom above the live opcode range so adding an
/// opcode never needs a registry change.
pub const MAX_OPCODE: usize = 31;

/// Cap on dynamic label slots (peers, contraction pairs) so a hostile
/// or runaway workload cannot grow the registry without bound;
/// registrations past the cap all share one overflow slot.
pub const MAX_DYNAMIC_SLOTS: usize = 64;

/// A monotonically-increasing event count. `Relaxed` everywhere:
/// these are statistics, not synchronization.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-written-wins `f64` gauge (stored as IEEE bits in an
/// `AtomicU64`).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Log2-bucketed histogram with sum/count/max — the PR-1 coordinator
/// latency histogram generalized and shared (the coordinator's
/// `Metrics` now embeds one of these). Recording is three relaxed
/// adds plus a `fetch_max`; percentile reads return the upper edge of
/// the bucket holding the p-quantile (accurate to within 2×).
#[derive(Debug)]
pub struct Histo {
    sum: AtomicU64,
    count: AtomicU64,
    max: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Default for Histo {
    fn default() -> Self {
        Self::new()
    }
}

impl Histo {
    pub fn new() -> Self {
        Self {
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
            max: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Record one sample (µs for latencies; dimensionless for sizes).
    #[inline]
    pub fn record(&self, v: u64) {
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        let idx = (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1);
        if let Some(b) = self.buckets.get(idx) {
            b.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum() as f64 / n as f64
    }

    /// Snapshot of the raw (non-cumulative) bucket counts.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Approximate percentile: the upper edge of the log2 bucket
    /// containing the p-quantile. `p` in `[0, 1]`.
    pub fn percentile(&self, p: f64) -> u64 {
        let counts = self.bucket_counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * p.clamp(0.0, 1.0)).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i == 0 { 1 } else { 1u64 << i };
            }
        }
        1u64 << (HIST_BUCKETS - 1)
    }
}

/// Per-opcode request-serving stats (the STATS-asymmetry fix: the
/// store server now measures every RPC, not just the coordinator
/// pool).
#[derive(Debug, Default)]
pub struct OpStats {
    pub requests: Counter,
    pub errors: Counter,
    /// end-to-end request latency (decode → response serialized), µs
    pub latency_us: Histo,
}

/// One replication channel's exported state. Handed out as an `Arc`
/// by [`Registry::register_peer`] so the replicator writes lock-free.
#[derive(Debug)]
pub struct PeerObs {
    pub addr: String,
    /// monotonic ms ([`now_ms`]) of the last tick on which this
    /// channel was fully settled; `u64::MAX` = never. The exported
    /// lag gauge is `now_ms() − last_settled_ms`.
    last_settled_ms: AtomicU64,
    pub bytes_shipped: Counter,
    pub ships: Counter,
    pub full_ships: Counter,
}

impl PeerObs {
    fn new(addr: String) -> Self {
        Self {
            addr,
            last_settled_ms: AtomicU64::new(u64::MAX),
            bytes_shipped: Counter::new(),
            ships: Counter::new(),
            full_ships: Counter::new(),
        }
    }

    /// Record one delivered frame.
    pub fn note_ship(&self, bytes: u64, full: bool) {
        self.ships.inc();
        self.bytes_shipped.add(bytes);
        if full {
            self.full_ships.inc();
        }
    }

    /// Mark this channel settled (everything acked through the probed
    /// stamp) as of `now` ([`now_ms`]).
    pub fn note_settled(&self, now: u64) {
        self.last_settled_ms.store(now, Ordering::Relaxed);
    }

    /// `Some(lag in ms)` once the channel has settled at least once.
    pub fn lag_ms(&self, now: u64) -> Option<u64> {
        let last = self.last_settled_ms.load(Ordering::Relaxed);
        if last == u64::MAX {
            None
        } else {
            Some(now.saturating_sub(last))
        }
    }
}

/// Live accuracy of one CONTRACT pair: the observed per-repeat
/// residual spread vs the paper's `8·‖A‖‖B‖/√Πm` deviation bound —
/// the Ahle–Knudsen-style guarantee as a gauge instead of only a
/// bench assertion. See `store::tensor::contract::contract_accuracy`
/// for what exactly is measured.
#[derive(Debug)]
pub struct ContractObs {
    /// `"a_name/b_name"`
    pub pair: String,
    pub residual: Gauge,
    pub bound: Gauge,
    /// `residual / bound` — healthy sketches sit well below 1.0
    pub ratio: Gauge,
    pub contracts: Counter,
}

impl ContractObs {
    fn new(pair: String) -> Self {
        Self {
            pair,
            residual: Gauge::new(),
            bound: Gauge::new(),
            ratio: Gauge::new(),
            contracts: Counter::new(),
        }
    }
}

/// The process-global metric surface. Every field is recordable
/// lock-free; the two mutex-guarded tables are touched only at
/// registration and render time.
#[derive(Debug)]
pub struct Registry {
    /// per-opcode RPC stats, indexed by wire opcode (slot 0 = unknown)
    rpc: [OpStats; MAX_OPCODE + 1],

    // ---- WAL / group commit ----
    /// successful physical appends (one per leader group write or
    /// per-record commit)
    pub wal_appends: Counter,
    /// framed bytes durably appended
    pub wal_bytes: Counter,
    /// `sync_data` latency per append, µs (fsync mode only)
    pub wal_fsync_us: Histo,
    /// frames coalesced per leader group write (the group-commit win,
    /// as a distribution)
    pub wal_group_frames: Histo,
    /// snapshot + WAL rotations completed
    pub wal_rotations: Counter,
    /// fail-stop transitions (a WAL write failed; the log refused
    /// further appends)
    pub wal_fail_stops: Counter,

    // ---- scan cache ----
    /// scans answered from a current cache stamp (no work)
    pub scan_hits: Counter,
    /// incremental pending-delta folds
    pub scan_folds: Counter,
    /// full K-way re-merges (post-rotation / raced fallback)
    pub scan_rebuilds: Counter,

    // ---- kernel dispatch ----
    /// scalar-walk dispatches (per batch op)
    pub kernel_scalar: Counter,
    /// portable-lane tile dispatches (per tile)
    pub kernel_portable: Counter,
    /// AVX2 tile dispatches (per tile)
    pub kernel_avx2: Counter,

    // ---- fault plane (debug builds arm it; release counts stay 0) ----
    pub fault_injections: Counter,

    // ---- replication ----
    pub repl_ticks: Counter,
    pub repl_settled_ticks: Counter,
    peers: Mutex<Vec<Arc<PeerObs>>>,

    // ---- tensor plane accuracy ----
    pub contracts_total: Counter,
    contracts: Mutex<Vec<Arc<ContractObs>>>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    pub fn new() -> Self {
        Self {
            rpc: std::array::from_fn(|_| OpStats::default()),
            wal_appends: Counter::new(),
            wal_bytes: Counter::new(),
            wal_fsync_us: Histo::new(),
            wal_group_frames: Histo::new(),
            wal_rotations: Counter::new(),
            wal_fail_stops: Counter::new(),
            scan_hits: Counter::new(),
            scan_folds: Counter::new(),
            scan_rebuilds: Counter::new(),
            kernel_scalar: Counter::new(),
            kernel_portable: Counter::new(),
            kernel_avx2: Counter::new(),
            fault_injections: Counter::new(),
            repl_ticks: Counter::new(),
            repl_settled_ticks: Counter::new(),
            peers: Mutex::new(Vec::new()),
            contracts_total: Counter::new(),
            contracts: Mutex::new(Vec::new()),
        }
    }

    /// Record one served request: opcode, end-to-end latency, and
    /// whether the response was `STATUS_OK`. Opcodes above
    /// [`MAX_OPCODE`] account to slot 0 (unknown) — never a panic.
    pub fn rpc_observe(&self, opcode: u8, us: u64, ok: bool) {
        let slot = if (opcode as usize) <= MAX_OPCODE { opcode as usize } else { 0 };
        if let Some(st) = self.rpc.get(slot) {
            st.requests.inc();
            if !ok {
                st.errors.inc();
            }
            st.latency_us.record(us);
        }
    }

    /// Per-opcode stats, if the opcode is in table range.
    pub fn rpc(&self, opcode: u8) -> Option<&OpStats> {
        self.rpc.get(opcode as usize)
    }

    /// Register (or look up) the exported slot for one replication
    /// peer. Idempotent per address; past [`MAX_DYNAMIC_SLOTS`] every
    /// new address shares the overflow slot.
    pub fn register_peer(&self, addr: &str) -> Arc<PeerObs> {
        let mut peers = match self.peers.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if let Some(p) = peers.iter().find(|p| p.addr == addr) {
            return p.clone();
        }
        let effective = if peers.len() >= MAX_DYNAMIC_SLOTS {
            "overflow".to_string()
        } else {
            addr.to_string()
        };
        if let Some(p) = peers.iter().find(|p| p.addr == effective) {
            return p.clone();
        }
        let slot = Arc::new(PeerObs::new(effective));
        peers.push(slot.clone());
        slot
    }

    /// Update the live accuracy gauge for one contraction pair.
    pub fn note_contract(&self, a_name: &str, b_name: &str, residual: f64, bound: f64) {
        self.contracts_total.inc();
        let pair = format!("{a_name}/{b_name}");
        let mut slots = match self.contracts.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let slot = match slots.iter().find(|c| c.pair == pair) {
            Some(c) => c.clone(),
            None => {
                let key =
                    if slots.len() >= MAX_DYNAMIC_SLOTS { "overflow".to_string() } else { pair };
                match slots.iter().find(|c| c.pair == key) {
                    Some(c) => c.clone(),
                    None => {
                        let c = Arc::new(ContractObs::new(key));
                        slots.push(c.clone());
                        c
                    }
                }
            }
        };
        drop(slots);
        slot.contracts.inc();
        slot.residual.set(residual);
        slot.bound.set(bound);
        slot.ratio.set(if bound > 0.0 { residual / bound } else { 0.0 });
    }

    /// Registered peer slots (render + `hocs top`).
    pub fn peer_slots(&self) -> Vec<Arc<PeerObs>> {
        match self.peers.lock() {
            Ok(g) => g.clone(),
            Err(p) => p.into_inner().clone(),
        }
    }

    /// Registered contraction-pair slots.
    pub fn contract_slots(&self) -> Vec<Arc<ContractObs>> {
        match self.contracts.lock() {
            Ok(g) => g.clone(),
            Err(p) => p.into_inner().clone(),
        }
    }

    /// Render the whole registry as Prometheus-style text. Panic-free
    /// by construction (served through the METRICS opcode).
    pub fn render_into(&self, out: &mut String) {
        use super::expo::{render_histogram, render_sample};
        // per-opcode RPC families: every table opcode renders its
        // counters (zeros included — stable names for scrapers), but
        // histograms only once they hold samples
        for o in crate::store::wire_ops::ALL {
            let Some(st) = self.rpc.get(o.code as usize) else { continue };
            render_sample(
                out,
                "hocs_rpc_requests_total",
                &[("op", o.name)],
                st.requests.get() as f64,
            );
        }
        for o in crate::store::wire_ops::ALL {
            let Some(st) = self.rpc.get(o.code as usize) else { continue };
            render_sample(out, "hocs_rpc_errors_total", &[("op", o.name)], st.errors.get() as f64);
        }
        for o in crate::store::wire_ops::ALL {
            let Some(st) = self.rpc.get(o.code as usize) else { continue };
            if st.latency_us.count() > 0 {
                render_histogram(out, "hocs_rpc_latency_us", &[("op", o.name)], &st.latency_us);
            }
        }
        if let Some(st) = self.rpc.first() {
            if st.requests.get() > 0 {
                render_sample(
                    out,
                    "hocs_rpc_requests_total",
                    &[("op", "UNKNOWN")],
                    st.requests.get() as f64,
                );
            }
        }

        render_sample(out, "hocs_wal_appends_total", &[], self.wal_appends.get() as f64);
        render_sample(out, "hocs_wal_bytes_total", &[], self.wal_bytes.get() as f64);
        render_sample(out, "hocs_wal_rotations_total", &[], self.wal_rotations.get() as f64);
        render_sample(out, "hocs_wal_fail_stops_total", &[], self.wal_fail_stops.get() as f64);
        render_histogram(out, "hocs_wal_fsync_us", &[], &self.wal_fsync_us);
        render_histogram(out, "hocs_wal_group_frames", &[], &self.wal_group_frames);

        render_sample(out, "hocs_scan_cache_hits_total", &[], self.scan_hits.get() as f64);
        render_sample(out, "hocs_scan_cache_folds_total", &[], self.scan_folds.get() as f64);
        render_sample(out, "hocs_scan_cache_rebuilds_total", &[], self.scan_rebuilds.get() as f64);
        let scans = self.scan_hits.get() + self.scan_folds.get() + self.scan_rebuilds.get();
        let ratio = if scans == 0 { 0.0 } else { self.scan_hits.get() as f64 / scans as f64 };
        render_sample(out, "hocs_scan_cache_hit_ratio", &[], ratio);

        render_sample(
            out,
            "hocs_kernel_dispatch_total",
            &[("path", "scalar")],
            self.kernel_scalar.get() as f64,
        );
        render_sample(
            out,
            "hocs_kernel_dispatch_total",
            &[("path", "portable")],
            self.kernel_portable.get() as f64,
        );
        render_sample(
            out,
            "hocs_kernel_dispatch_total",
            &[("path", "avx2")],
            self.kernel_avx2.get() as f64,
        );

        render_sample(out, "hocs_fault_injections_total", &[], self.fault_injections.get() as f64);

        render_sample(out, "hocs_repl_ticks_total", &[], self.repl_ticks.get() as f64);
        render_sample(
            out,
            "hocs_repl_settled_ticks_total",
            &[],
            self.repl_settled_ticks.get() as f64,
        );
        let now = now_ms();
        for p in self.peer_slots() {
            let synced = p.lag_ms(now);
            render_sample(
                out,
                "hocs_repl_peer_synced",
                &[("peer", &p.addr)],
                if synced.is_some() { 1.0 } else { 0.0 },
            );
            if let Some(lag) = synced {
                render_sample(out, "hocs_repl_peer_lag_ms", &[("peer", &p.addr)], lag as f64);
            }
            render_sample(
                out,
                "hocs_repl_peer_bytes_total",
                &[("peer", &p.addr)],
                p.bytes_shipped.get() as f64,
            );
            render_sample(
                out,
                "hocs_repl_peer_ships_total",
                &[("peer", &p.addr)],
                p.ships.get() as f64,
            );
            render_sample(
                out,
                "hocs_repl_peer_full_ships_total",
                &[("peer", &p.addr)],
                p.full_ships.get() as f64,
            );
        }

        render_sample(out, "hocs_contracts_total", &[], self.contracts_total.get() as f64);
        for c in self.contract_slots() {
            render_sample(out, "hocs_contract_residual", &[("pair", &c.pair)], c.residual.get());
            render_sample(out, "hocs_contract_bound", &[("pair", &c.pair)], c.bound.get());
            render_sample(out, "hocs_contract_ratio", &[("pair", &c.pair)], c.ratio.get());
        }
    }
}

/// The process-global registry every instrumentation site records
/// into. Unit tests that need isolation construct their own
/// [`Registry`] instead.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// Monotonic milliseconds since the first observability call in this
/// process — the clock behind replication-lag gauges and the tracing
/// ring's span stamps.
pub fn now_ms() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_millis() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histo_percentiles_bracket_samples() {
        let h = Histo::new();
        for _ in 0..99 {
            h.record(100);
        }
        h.record(50_000);
        assert_eq!(h.count(), 100);
        assert_eq!(h.max(), 50_000);
        let p50 = h.percentile(0.5);
        assert!((64..=128).contains(&p50), "p50={p50}");
        assert!(h.percentile(0.999) >= 32_768);
    }

    #[test]
    fn rpc_slots_are_total_over_u8() {
        let r = Registry::new();
        // no opcode value may panic or be dropped
        for code in 0..=u8::MAX {
            r.rpc_observe(code, 5, code % 2 == 0);
        }
        let total: u64 = (0..=MAX_OPCODE)
            .filter_map(|i| r.rpc.get(i))
            .map(|s| s.requests.get())
            .sum();
        assert_eq!(total, 256);
    }

    #[test]
    fn peer_registration_is_idempotent_and_bounded() {
        let r = Registry::new();
        let a = r.register_peer("n1:7000");
        let b = r.register_peer("n1:7000");
        assert!(Arc::ptr_eq(&a, &b));
        for i in 0..(MAX_DYNAMIC_SLOTS + 10) {
            r.register_peer(&format!("peer-{i}"));
        }
        assert!(r.peer_slots().len() <= MAX_DYNAMIC_SLOTS + 2);
    }

    #[test]
    fn contract_gauge_tracks_last_value() {
        let r = Registry::new();
        r.note_contract("a", "b", 0.5, 2.0);
        r.note_contract("a", "b", 1.0, 4.0);
        let slots = r.contract_slots();
        assert_eq!(slots.len(), 1);
        let c = &slots[0];
        assert_eq!(c.pair, "a/b");
        assert_eq!(c.contracts.get(), 2);
        assert_eq!(c.residual.get(), 1.0);
        assert_eq!(c.ratio.get(), 0.25);
    }
}
