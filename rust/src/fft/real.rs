//! Real-input FFT path (§Perf, batch-engine PR).
//!
//! The sketch combines only ever transform *real* buffers — MTS/CTS
//! sketches — and only ever need *real* inverse transforms, so running
//! them through the fully complex machinery wastes half the arithmetic
//! and memory traffic. [`RealFftPlan`] exploits conjugate symmetry:
//!
//! - even `n`: the classic pack-two-reals-per-complex scheme — the real
//!   signal is viewed as an `n/2`-point complex signal
//!   `z[j] = x[2j] + i·x[2j+1]`, transformed with one half-length
//!   complex FFT, then untangled into the `n/2 + 1` non-redundant
//!   spectrum bins;
//! - odd `n` (rare on sketch paths — sketch dims are typically even):
//!   falls back to the full complex transform and keeps only the
//!   non-redundant half.
//!
//! On top of the 1-D plan sit [`rfft2`] / [`irfft2`] (row RFFTs, then
//! complex column FFTs over the `cols/2 + 1` retained columns) and the
//! half-spectrum convolutions [`circular_convolve_real`] /
//! [`circular_convolve2_real`] that the Kron / Tucker / TT / CP /
//! covariance combines run on. Plans are cached thread-locally (see
//! [`real_plan`]) so a batch of combines shares twiddles and scratch.

use super::{plan, Complex, Direction, FftPlan};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// Number of non-redundant spectrum bins of a length-`n` real signal.
#[inline]
pub fn spectrum_len(n: usize) -> usize {
    n / 2 + 1
}

/// A cached plan for length-`n` real-input transforms.
#[derive(Debug)]
pub struct RealFftPlan {
    pub n: usize,
    kind: RealKind,
}

#[derive(Debug)]
enum RealKind {
    /// even n: half-length complex FFT + spectrum untangle
    Even {
        /// complex plan of length n/2
        half: Rc<FftPlan>,
        /// w[k] = exp(-2πi·k/n), k = 0..=n/2
        twiddles: Vec<Complex>,
        /// reused packing buffer of length n/2
        scratch: RefCell<Vec<Complex>>,
    },
    /// odd n: full complex transform, truncated to the half spectrum
    Odd {
        full: Rc<FftPlan>,
        scratch: RefCell<Vec<Complex>>,
    },
}

impl RealFftPlan {
    pub fn new(n: usize) -> Self {
        assert!(n > 0, "real FFT length must be positive");
        if n % 2 == 0 {
            let m = n / 2;
            let half = plan(m);
            let mut twiddles = Vec::with_capacity(m + 1);
            for k in 0..=m {
                let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
                twiddles.push(Complex::from_polar(1.0, ang));
            }
            Self {
                n,
                kind: RealKind::Even {
                    half,
                    twiddles,
                    scratch: RefCell::new(vec![Complex::ZERO; m]),
                },
            }
        } else {
            Self {
                n,
                kind: RealKind::Odd {
                    full: plan(n),
                    scratch: RefCell::new(vec![Complex::ZERO; n]),
                },
            }
        }
    }

    /// Length of the half spectrum this plan produces/consumes.
    #[inline]
    pub fn spectrum_len(&self) -> usize {
        spectrum_len(self.n)
    }

    /// Forward transform of the length-`n` real signal `x` into the
    /// `n/2 + 1` non-redundant bins (same sign/normalization convention
    /// as [`FftPlan::transform`]: unnormalized forward).
    pub fn forward(&self, x: &[f64], out: &mut [Complex]) {
        assert_eq!(x.len(), self.n, "input length != plan length");
        assert_eq!(out.len(), self.spectrum_len(), "output length != n/2 + 1");
        match &self.kind {
            RealKind::Even { half, twiddles, scratch } => {
                let m = self.n / 2;
                let mut z = scratch.borrow_mut();
                for j in 0..m {
                    z[j] = Complex::new(x[2 * j], x[2 * j + 1]);
                }
                half.transform(&mut z, Direction::Forward);
                // untangle: X[k] = Xe[k] + w^k·Xo[k], where
                //   Xe[k] = (Z[k] + conj(Z[m-k]))/2      (even samples)
                //   Xo[k] = (Z[k] - conj(Z[m-k]))/(2i)   (odd samples)
                // with Z[m] ≡ Z[0].
                for k in 0..=m {
                    let zk = if k < m { z[k] } else { z[0] };
                    let zmk = if k == 0 { z[0].conj() } else { z[m - k].conj() };
                    let xe = (zk + zmk).scale(0.5);
                    let d = zk - zmk;
                    // d / (2i) == d · (-i/2)
                    let xo = Complex::new(d.im * 0.5, -d.re * 0.5);
                    out[k] = xe + twiddles[k] * xo;
                }
            }
            RealKind::Odd { full, scratch } => {
                let mut buf = scratch.borrow_mut();
                for (b, &v) in buf.iter_mut().zip(x.iter()) {
                    *b = Complex::new(v, 0.0);
                }
                full.transform(&mut buf, Direction::Forward);
                out.copy_from_slice(&buf[..self.spectrum_len()]);
            }
        }
    }

    /// Inverse transform of the half spectrum `spec` (length `n/2 + 1`)
    /// back to a length-`n` real signal, including the 1/n
    /// normalization, so `inverse(forward(x)) == x`.
    pub fn inverse(&self, spec: &[Complex], out: &mut [f64]) {
        assert_eq!(spec.len(), self.spectrum_len(), "spectrum length != n/2 + 1");
        assert_eq!(out.len(), self.n, "output length != plan length");
        match &self.kind {
            RealKind::Even { half, twiddles, scratch } => {
                let m = self.n / 2;
                let mut z = scratch.borrow_mut();
                // re-tangle: Z[k] = Xe[k] + i·Xo[k] with
                //   Xe[k] = (X[k] + conj(X[m-k]))/2
                //   Xo[k] = (X[k] - conj(X[m-k]))·w^{-k}/2
                for k in 0..m {
                    let xk = spec[k];
                    let xmk = spec[m - k].conj();
                    let xe = (xk + xmk).scale(0.5);
                    let xo = (xk - xmk).scale(0.5) * twiddles[k].conj();
                    // Z[k] = Xe[k] + i·Xo[k]
                    z[k] = Complex::new(xe.re - xo.im, xe.im + xo.re);
                }
                half.transform(&mut z, Direction::Inverse);
                for j in 0..m {
                    out[2 * j] = z[j].re;
                    out[2 * j + 1] = z[j].im;
                }
            }
            RealKind::Odd { full, scratch } => {
                let n = self.n;
                let hc = self.spectrum_len();
                let mut buf = scratch.borrow_mut();
                buf[..hc].copy_from_slice(spec);
                for k in 1..hc {
                    buf[n - k] = spec[k].conj();
                }
                full.transform(&mut buf, Direction::Inverse);
                for (o, b) in out.iter_mut().zip(buf.iter()) {
                    *o = b.re;
                }
            }
        }
    }
}

thread_local! {
    static REAL_PLAN_CACHE: RefCell<HashMap<usize, Rc<RealFftPlan>>> =
        RefCell::new(HashMap::new());
}

/// Fetch (or build) the thread-local cached real plan for length `n`.
/// Worker threads in the coordinator each hold their own cache, so a
/// batch of same-shape combines pays plan construction once per worker.
pub fn real_plan(n: usize) -> Rc<RealFftPlan> {
    REAL_PLAN_CACHE.with(|c| {
        c.borrow_mut()
            .entry(n)
            .or_insert_with(|| Rc::new(RealFftPlan::new(n)))
            .clone()
    })
}

/// Forward real FFT; returns the `n/2 + 1` non-redundant bins.
pub fn rfft(x: &[f64]) -> Vec<Complex> {
    let p = real_plan(x.len());
    let mut out = vec![Complex::ZERO; p.spectrum_len()];
    p.forward(x, &mut out);
    out
}

/// Inverse of [`rfft`]: half spectrum (length `n/2 + 1`) → length-`n`
/// real signal.
pub fn irfft(spec: &[Complex], n: usize) -> Vec<f64> {
    let p = real_plan(n);
    let mut out = vec![0.0; n];
    p.inverse(spec, &mut out);
    out
}

/// 2-D real-input FFT of a row-major `rows × cols` matrix. Returns the
/// row-major `rows × (cols/2 + 1)` slab of the full spectrum — the
/// remaining columns are redundant by `S[r, cols-c] =
/// conj(S[(rows-r) % rows, c])`.
pub fn rfft2(x: &[f64], rows: usize, cols: usize) -> Vec<Complex> {
    assert_eq!(x.len(), rows * cols);
    let rp = real_plan(cols);
    let hc = rp.spectrum_len();
    let mut out = vec![Complex::ZERO; rows * hc];
    for r in 0..rows {
        rp.forward(&x[r * cols..(r + 1) * cols], &mut out[r * hc..(r + 1) * hc]);
    }
    let cp = plan(rows);
    let mut col = vec![Complex::ZERO; rows];
    for c in 0..hc {
        for r in 0..rows {
            col[r] = out[r * hc + c];
        }
        cp.transform(&mut col, Direction::Forward);
        for r in 0..rows {
            out[r * hc + c] = col[r];
        }
    }
    out
}

/// Inverse of [`rfft2`]: `rows × (cols/2 + 1)` half-spectrum slab →
/// `rows × cols` real matrix (normalized, so `irfft2(rfft2(x)) == x`).
pub fn irfft2(spec: &[Complex], rows: usize, cols: usize) -> Vec<f64> {
    let rp = real_plan(cols);
    let hc = rp.spectrum_len();
    assert_eq!(spec.len(), rows * hc);
    let mut buf = spec.to_vec();
    let cp = plan(rows);
    let mut col = vec![Complex::ZERO; rows];
    for c in 0..hc {
        for r in 0..rows {
            col[r] = buf[r * hc + c];
        }
        cp.transform(&mut col, Direction::Inverse);
        for r in 0..rows {
            buf[r * hc + c] = col[r];
        }
    }
    let mut out = vec![0.0; rows * cols];
    for r in 0..rows {
        rp.inverse(&buf[r * hc..(r + 1) * hc], &mut out[r * cols..(r + 1) * cols]);
    }
    out
}

/// Circular convolution of two real vectors via the half-spectrum path
/// (the real-input counterpart of [`super::circular_convolve`]).
pub fn circular_convolve_real(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut fa = rfft(a);
    let fb = rfft(b);
    for (x, y) in fa.iter_mut().zip(fb.iter()) {
        *x = *x * *y;
    }
    irfft(&fa, n)
}

/// 2-D circular convolution of two real `rows × cols` matrices via the
/// half-spectrum path — the real-input MTS Kronecker combine of
/// Lemma B.1. Versus the packed complex path
/// ([`super::circular_convolve2`]) this runs 1.5 half-size transforms
/// instead of 2 full-size ones, touches half the spectral memory, and
/// skips the negated-frequency gather pass.
pub fn circular_convolve2_real(a: &[f64], b: &[f64], rows: usize, cols: usize) -> Vec<f64> {
    assert_eq!(a.len(), rows * cols);
    assert_eq!(b.len(), rows * cols);
    let mut fa = rfft2(a, rows, cols);
    let fb = rfft2(b, rows, cols);
    for (x, y) in fa.iter_mut().zip(fb.iter()) {
        *x = *x * *y;
    }
    irfft2(&fa, rows, cols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{circular_convolve, circular_convolve2, fft, fft_real, ifft};
    use crate::rng::Pcg64;

    /// The satellite sweep: every length class the crate meets — powers
    /// of two, even composites, odd composites, and primes (Bluestein).
    const LENGTH_SWEEP: &[usize] = &[
        1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 12, 15, 16, 17, 24, 30, 31, 32, 33, 48, 64, 97, 100, 127,
        128, 251, 256,
    ];

    #[test]
    fn real_forward_matches_complex_across_length_sweep() {
        for &n in LENGTH_SWEEP {
            let mut rng = Pcg64::new(100 + n as u64);
            let x = rng.normal_vec(n);
            let got = rfft(&x);
            let want = fft_real(&x);
            assert_eq!(got.len(), n / 2 + 1);
            for (k, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                assert!(
                    (*g - *w).abs() < 1e-9,
                    "n={n} bin {k}: {g:?} vs {w:?} (|Δ|={})",
                    (*g - *w).abs()
                );
            }
        }
    }

    #[test]
    fn real_roundtrip_across_length_sweep() {
        for &n in LENGTH_SWEEP {
            let mut rng = Pcg64::new(200 + n as u64);
            let x = rng.normal_vec(n);
            let rec = irfft(&rfft(&x), n);
            for (i, (r, v)) in rec.iter().zip(x.iter()).enumerate() {
                assert!((r - v).abs() < 1e-9, "n={n} idx {i}: {r} vs {v}");
            }
        }
    }

    #[test]
    fn bluestein_roundtrip_prime_lengths() {
        // the non-power-of-two (chirp-z) path at odd / prime lengths
        for &n in &[3usize, 7, 11, 13, 23, 29, 61, 97, 127, 251, 509, 1021] {
            let mut rng = Pcg64::new(300 + n as u64);
            let x: Vec<Complex> =
                (0..n).map(|_| Complex::new(rng.normal(), rng.normal())).collect();
            let mut buf = x.clone();
            fft(&mut buf);
            ifft(&mut buf);
            for (i, (b, v)) in buf.iter().zip(x.iter()).enumerate() {
                assert!(
                    (*b - *v).abs() < 1e-9 * (n as f64 + 1.0),
                    "n={n} idx {i}: {b:?} vs {v:?}"
                );
            }
        }
    }

    #[test]
    fn rfft2_matches_complex_fft2_half_plane() {
        use crate::fft::fft2_real;
        for &(r, c) in &[(4usize, 4usize), (3, 5), (8, 6), (5, 8), (10, 10), (1, 7), (7, 1)] {
            let mut rng = Pcg64::new((r * 37 + c) as u64);
            let x = rng.normal_vec(r * c);
            let got = rfft2(&x, r, c);
            let want = fft2_real(&x, r, c);
            let hc = c / 2 + 1;
            for row in 0..r {
                for col in 0..hc {
                    let g = got[row * hc + col];
                    let w = want[row * c + col];
                    assert!((g - w).abs() < 1e-9, "({r}x{c}) at ({row},{col}): {g:?} vs {w:?}");
                }
            }
        }
    }

    #[test]
    fn rfft2_roundtrip() {
        for &(r, c) in &[(4usize, 4usize), (3, 5), (8, 6), (10, 10), (1, 7), (6, 1), (2, 2)] {
            let mut rng = Pcg64::new((r * 101 + c) as u64);
            let x = rng.normal_vec(r * c);
            let rec = irfft2(&rfft2(&x, r, c), r, c);
            for (i, (a, b)) in rec.iter().zip(x.iter()).enumerate() {
                assert!((a - b).abs() < 1e-9, "({r}x{c}) idx {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn convolve_real_matches_complex_path() {
        for &n in &[4usize, 7, 16, 30, 33, 64, 100] {
            let mut rng = Pcg64::new(n as u64);
            let a = rng.normal_vec(n);
            let b = rng.normal_vec(n);
            let got = circular_convolve_real(&a, &b);
            let want = circular_convolve(&a, &b);
            for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                assert!((g - w).abs() < 1e-9, "n={n} idx {i}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn convolve2_real_matches_complex_path_across_sweep() {
        // the acceptance sweep: the optimized path must agree with the
        // packed complex path to ≤ 1e-9 absolute error
        for &(r, c) in &[
            (4usize, 4usize),
            (5, 6),
            (6, 5),
            (7, 7),
            (8, 8),
            (9, 12),
            (16, 16),
            (17, 13),
            (32, 32),
            (64, 64),
        ] {
            let mut rng = Pcg64::new((r * 13 + c) as u64);
            let a = rng.normal_vec(r * c);
            let b = rng.normal_vec(r * c);
            let got = circular_convolve2_real(&a, &b, r, c);
            let want = circular_convolve2(&a, &b, r, c);
            for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                assert!((g - w).abs() < 1e-9, "({r}x{c}) idx {i}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn convolve2_real_matches_direct() {
        let mut rng = Pcg64::new(99);
        let (r, c) = (5usize, 6usize);
        let a = rng.normal_vec(r * c);
        let b = rng.normal_vec(r * c);
        let got = circular_convolve2_real(&a, &b, r, c);
        for kr in 0..r {
            for kc in 0..c {
                let mut want = 0.0;
                for i in 0..r {
                    for j in 0..c {
                        want += a[i * c + j] * b[((kr + r - i) % r) * c + (kc + c - j) % c];
                    }
                }
                let g = got[kr * c + kc];
                assert!((g - want).abs() < 1e-9, "({kr},{kc}): {g} vs {want}");
            }
        }
    }

    #[test]
    fn real_plan_cache_reuses_plans() {
        let p1 = real_plan(48);
        let p2 = real_plan(48);
        assert!(Rc::ptr_eq(&p1, &p2));
    }

    #[test]
    fn trivial_lengths() {
        // n = 1 and n = 2 hit the degenerate plan branches
        assert_eq!(irfft(&rfft(&[3.5]), 1), vec![3.5]);
        let rec = irfft(&rfft(&[1.0, -2.0]), 2);
        assert!((rec[0] - 1.0).abs() < 1e-12 && (rec[1] + 2.0).abs() < 1e-12);
    }
}
