//! Minimal complex-number type for the FFT substrate (no external crates).

/// Complex number with f64 components. `Copy`, laid out as two f64s so a
/// `&[Complex]` can be reinterpreted as interleaved re/im when marshalled
/// to XLA literals.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
#[repr(C)]
pub struct Complex {
    pub re: f64,
    pub im: f64,
}

impl Complex {
    pub const ZERO: Complex = Complex { re: 0.0, im: 0.0 };
    pub const ONE: Complex = Complex { re: 1.0, im: 0.0 };
    pub const I: Complex = Complex { re: 0.0, im: 1.0 };

    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        Self { re, im }
    }

    #[inline]
    pub fn from_polar(r: f64, theta: f64) -> Self {
        Self { re: r * theta.cos(), im: r * theta.sin() }
    }

    #[inline]
    pub fn conj(self) -> Self {
        Self { re: self.re, im: -self.im }
    }

    #[inline]
    pub fn scale(self, s: f64) -> Self {
        Self { re: self.re * s, im: self.im * s }
    }

    #[inline]
    pub fn norm_sq(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    #[inline]
    pub fn abs(self) -> f64 {
        self.norm_sq().sqrt()
    }
}

impl std::ops::Add for Complex {
    type Output = Complex;
    #[inline]
    fn add(self, o: Complex) -> Complex {
        Complex { re: self.re + o.re, im: self.im + o.im }
    }
}

impl std::ops::Sub for Complex {
    type Output = Complex;
    #[inline]
    fn sub(self, o: Complex) -> Complex {
        Complex { re: self.re - o.re, im: self.im - o.im }
    }
}

impl std::ops::Mul for Complex {
    type Output = Complex;
    #[inline]
    fn mul(self, o: Complex) -> Complex {
        Complex {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

impl std::ops::Neg for Complex {
    type Output = Complex;
    #[inline]
    fn neg(self) -> Complex {
        Complex { re: -self.re, im: -self.im }
    }
}

impl std::ops::AddAssign for Complex {
    #[inline]
    fn add_assign(&mut self, o: Complex) {
        self.re += o.re;
        self.im += o.im;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_identities() {
        let a = Complex::new(1.5, -2.0);
        let b = Complex::new(-0.5, 3.0);
        assert_eq!(a + b, Complex::new(1.0, 1.0));
        assert_eq!(a - b, Complex::new(2.0, -5.0));
        // (1.5 - 2i)(-0.5 + 3i) = -0.75 + 4.5i + i - (-6)·(-1)... compute:
        // re = 1.5*-0.5 - (-2)*3 = -0.75 + 6 = 5.25
        // im = 1.5*3 + (-2)*(-0.5) = 4.5 + 1 = 5.5
        assert_eq!(a * b, Complex::new(5.25, 5.5));
        assert_eq!(a * Complex::ONE, a);
        assert_eq!(a * Complex::I, Complex::new(2.0, 1.5));
    }

    #[test]
    fn polar_and_norm() {
        let c = Complex::from_polar(2.0, std::f64::consts::FRAC_PI_2);
        assert!((c.re - 0.0).abs() < 1e-12);
        assert!((c.im - 2.0).abs() < 1e-12);
        assert!((c.abs() - 2.0).abs() < 1e-12);
        assert!((c.norm_sq() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn conj_mul_is_norm() {
        let a = Complex::new(3.0, -4.0);
        let p = a * a.conj();
        assert!((p.re - 25.0).abs() < 1e-12);
        assert!(p.im.abs() < 1e-12);
    }
}
