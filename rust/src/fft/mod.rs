//! Fast Fourier transforms (1-D and 2-D), built from scratch.
//!
//! The sketched-Kronecker combine (`MTS(A⊗B) = IFFT2(FFT2(A') ∘ FFT2(B'))`,
//! Lemma B.1) and the TT combine (Algorithm 5) run entirely through this
//! module, so it supports **arbitrary lengths**:
//!
//! - power-of-two lengths: iterative radix-2 Cooley–Tukey with
//!   precomputed twiddle tables and bit-reversal permutation;
//! - everything else: Bluestein's chirp-z transform, which reduces any
//!   length-n DFT to three power-of-two FFTs of length ≥ 2n-1.
//!
//! Two input paths share that machinery:
//!
//! - the **complex path** ([`FftPlan`], [`fft2`], [`circular_convolve2`])
//!   — the general transform, kept as the parity oracle and for the
//!   packing ablation;
//! - the **real path** ([`real::RealFftPlan`], [`real::rfft2`],
//!   [`real::circular_convolve2_real`]) — the hot path for every sketch
//!   combine. Sketches are real, so conjugate symmetry halves the
//!   transform arithmetic and spectral memory (pack-two-reals-per-
//!   complex; see `real.rs`); all Kron / Tucker / TT / CP / covariance
//!   combines run on half spectra.
//!
//! [`FftPlan`] / [`real::RealFftPlan`] cache twiddles per length in
//! thread-local maps, so repeated and batched combines share plans and
//! scratch (the profile-guided fix recorded in EXPERIMENTS.md §Perf;
//! each coordinator worker thread warms its own cache).

pub mod complex;
pub mod real;

pub use complex::Complex;
pub use real::{
    circular_convolve2_real, circular_convolve_real, irfft, irfft2, real_plan, rfft, rfft2,
    RealFftPlan,
};

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// Direction of the transform.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    Forward,
    Inverse,
}

/// A cached plan for length-`n` transforms.
///
/// For power-of-two `n` this holds twiddle factors and the bit-reversal
/// table. For general `n` it holds the Bluestein chirp and the
/// pre-transformed chirp filter at the padded power-of-two length.
#[derive(Debug)]
pub struct FftPlan {
    pub n: usize,
    kind: PlanKind,
}

#[derive(Debug)]
enum PlanKind {
    Radix2 {
        /// twiddles[s] holds the stage-s factors
        twiddles: Vec<Complex>,
        bitrev: Vec<u32>,
    },
    Bluestein {
        /// chirp[k] = exp(-i π k² / n)
        chirp: Vec<Complex>,
        /// FFT (length np) of the conjugate chirp filter
        filter_fft: Vec<Complex>,
        /// inner power-of-two plan of length np ≥ 2n-1
        inner: Box<FftPlan>,
        /// reused padded work buffer (plans are thread-local; §Perf —
        /// the per-transform allocation dominated small sketches)
        scratch: RefCell<Vec<Complex>>,
    },
}

impl FftPlan {
    pub fn new(n: usize) -> Self {
        assert!(n > 0, "FFT length must be positive");
        if n.is_power_of_two() {
            let mut twiddles = Vec::with_capacity(n.max(2) / 2);
            for k in 0..n / 2 {
                let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
                twiddles.push(Complex::from_polar(1.0, ang));
            }
            let bits = n.trailing_zeros();
            let bitrev = (0..n as u32)
                .map(|i| if bits == 0 { 0 } else { i.reverse_bits() >> (32 - bits) })
                .collect();
            Self { n, kind: PlanKind::Radix2 { twiddles, bitrev } }
        } else {
            let np = (2 * n - 1).next_power_of_two();
            let inner = Box::new(FftPlan::new(np));
            let mut chirp = Vec::with_capacity(n);
            for k in 0..n {
                // k² mod 2n computed in u128 to avoid overflow for large n
                let k2 = ((k as u128 * k as u128) % (2 * n as u128)) as f64;
                let ang = -std::f64::consts::PI * k2 / n as f64;
                chirp.push(Complex::from_polar(1.0, ang));
            }
            let mut filt = vec![Complex::ZERO; np];
            filt[0] = chirp[0].conj();
            for k in 1..n {
                let c = chirp[k].conj();
                filt[k] = c;
                filt[np - k] = c;
            }
            inner.transform(&mut filt, Direction::Forward);
            Self {
                n,
                kind: PlanKind::Bluestein {
                    chirp,
                    filter_fft: filt,
                    inner,
                    scratch: RefCell::new(vec![Complex::ZERO; np]),
                },
            }
        }
    }

    /// In-place transform of `data` (`data.len() == n`).
    ///
    /// The inverse transform includes the 1/n normalization, so
    /// `inverse(forward(x)) == x`.
    pub fn transform(&self, data: &mut [Complex], dir: Direction) {
        assert_eq!(data.len(), self.n, "data length != plan length");
        match &self.kind {
            PlanKind::Radix2 { twiddles, bitrev } => {
                radix2_in_place(data, twiddles, bitrev, dir);
                if dir == Direction::Inverse {
                    let scale = 1.0 / self.n as f64;
                    for x in data.iter_mut() {
                        *x = x.scale(scale);
                    }
                }
            }
            PlanKind::Bluestein { chirp, filter_fft, inner, scratch } => {
                let n = self.n;
                let np = inner.n;
                let mut buf_guard = scratch.borrow_mut();
                let buf: &mut [Complex] = &mut buf_guard;
                buf.fill(Complex::ZERO);
                // pre-chirp; for the inverse, conjugate the chirp
                for k in 0..n {
                    let c = if dir == Direction::Forward { chirp[k] } else { chirp[k].conj() };
                    buf[k] = data[k] * c;
                }
                inner.transform(buf, Direction::Forward);
                match dir {
                    Direction::Forward => {
                        for (b, f) in buf.iter_mut().zip(filter_fft.iter()) {
                            *b = *b * *f;
                        }
                    }
                    Direction::Inverse => {
                        // conjugate filter = FFT of chirp (not conj chirp);
                        // use conj symmetry: conj(FFT(conj x)) = IFFT(x)*np
                        for (b, f) in buf.iter_mut().zip(filter_fft.iter()) {
                            *b = *b * f.conj();
                        }
                    }
                }
                inner.transform(buf, Direction::Inverse);
                let scale = if dir == Direction::Inverse { 1.0 / n as f64 } else { 1.0 };
                for k in 0..n {
                    let c = if dir == Direction::Forward { chirp[k] } else { chirp[k].conj() };
                    data[k] = (buf[k] * c).scale(scale);
                }
            }
        }
    }
}

fn radix2_in_place(data: &mut [Complex], twiddles: &[Complex], bitrev: &[u32], dir: Direction) {
    let n = data.len();
    if n <= 1 {
        return;
    }
    for i in 0..n {
        let j = bitrev[i] as usize;
        if i < j {
            data.swap(i, j);
        }
    }
    let mut len = 2;
    while len <= n {
        let half = len / 2;
        let stride = n / len;
        for start in (0..n).step_by(len) {
            for k in 0..half {
                let tw = twiddles[k * stride];
                let tw = if dir == Direction::Inverse { tw.conj() } else { tw };
                let a = data[start + k];
                let b = data[start + k + half] * tw;
                data[start + k] = a + b;
                data[start + k + half] = a - b;
            }
        }
        len <<= 1;
    }
}

thread_local! {
    static PLAN_CACHE: RefCell<HashMap<usize, Rc<FftPlan>>> = RefCell::new(HashMap::new());
}

/// Fetch (or build) the thread-local cached plan for length `n`.
pub fn plan(n: usize) -> Rc<FftPlan> {
    PLAN_CACHE.with(|c| {
        c.borrow_mut()
            .entry(n)
            .or_insert_with(|| Rc::new(FftPlan::new(n)))
            .clone()
    })
}

/// Forward 1-D FFT (in place).
pub fn fft(data: &mut [Complex]) {
    plan(data.len()).transform(data, Direction::Forward);
}

/// Inverse 1-D FFT (in place, normalized).
pub fn ifft(data: &mut [Complex]) {
    plan(data.len()).transform(data, Direction::Inverse);
}

/// Forward FFT of a real signal; returns complex spectrum.
pub fn fft_real(x: &[f64]) -> Vec<Complex> {
    let mut buf: Vec<Complex> = x.iter().map(|&v| Complex::new(v, 0.0)).collect();
    fft(&mut buf);
    buf
}

/// 2-D FFT of row-major `rows × cols` data (in place).
pub fn fft2(data: &mut [Complex], rows: usize, cols: usize, dir: Direction) {
    assert_eq!(data.len(), rows * cols);
    let row_plan = plan(cols);
    for r in 0..rows {
        row_plan.transform(&mut data[r * cols..(r + 1) * cols], dir);
    }
    let col_plan = plan(rows);
    let mut col = vec![Complex::ZERO; rows];
    for c in 0..cols {
        for r in 0..rows {
            col[r] = data[r * cols + c];
        }
        col_plan.transform(&mut col, dir);
        for r in 0..rows {
            data[r * cols + c] = col[r];
        }
    }
}

/// 2-D FFT of a real row-major matrix; returns complex spectrum.
pub fn fft2_real(x: &[f64], rows: usize, cols: usize) -> Vec<Complex> {
    let mut buf: Vec<Complex> = x.iter().map(|&v| Complex::new(v, 0.0)).collect();
    fft2(&mut buf, rows, cols, Direction::Forward);
    buf
}

/// Inverse 2-D FFT returning only real parts (caller asserts realness).
pub fn ifft2_to_real(mut spec: Vec<Complex>, rows: usize, cols: usize) -> Vec<f64> {
    fft2(&mut spec, rows, cols, Direction::Inverse);
    spec.into_iter().map(|c| c.re).collect()
}

/// Reference (unpacked) 2-D convolution: three separate FFT2s. Kept for
/// the ablation bench (`hocs bench ablation`) that justifies the packed
/// implementation above; not used on any hot path.
pub fn circular_convolve2_unpacked(a: &[f64], b: &[f64], rows: usize, cols: usize) -> Vec<f64> {
    assert_eq!(a.len(), rows * cols);
    assert_eq!(b.len(), rows * cols);
    let mut fa = fft2_real(a, rows, cols);
    let fb = fft2_real(b, rows, cols);
    for (x, y) in fa.iter_mut().zip(fb.iter()) {
        *x = *x * *y;
    }
    ifft2_to_real(fa, rows, cols)
}

/// Circular (cyclic) convolution of two real vectors of equal length,
/// computed via FFT. This is exactly the count-sketch combine of
/// Pagh (2012): `CS(u ⊗ v) = CS(u) * CS(v)`.
pub fn circular_convolve(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut fa = fft_real(a);
    let fb = fft_real(b);
    for (x, y) in fa.iter_mut().zip(fb.iter()) {
        *x = *x * *y;
    }
    ifft(&mut fa);
    fa.into_iter().take(n).map(|c| c.re).collect()
}

/// 2-D circular convolution of two real `rows × cols` matrices via FFT2.
/// This is the MTS Kronecker combine of Lemma B.1.
///
/// Perf (see EXPERIMENTS.md §Perf): the two forward transforms are
/// packed into ONE complex FFT2 of `z = a + i·b`. By conjugate symmetry
/// of real-input spectra, `FFT(a)[k] = (Z[k] + conj(Z[-k]))/2` and
/// `FFT(b)[k] = (Z[k] − conj(Z[-k]))/(2i)`, and conveniently the
/// product is `FFT(a)∘FFT(b) = (Z[k]² − conj(Z[-k])²)/(4i)` — two
/// FFT2s total instead of three (−33% transform work).
pub fn circular_convolve2(a: &[f64], b: &[f64], rows: usize, cols: usize) -> Vec<f64> {
    assert_eq!(a.len(), rows * cols);
    assert_eq!(b.len(), rows * cols);
    let n = rows * cols;
    let mut z: Vec<Complex> = a.iter().zip(b.iter()).map(|(&x, &y)| Complex::new(x, y)).collect();
    fft2(&mut z, rows, cols, Direction::Forward);
    // index-reversed (negated frequency) lookup: (-r mod rows, -c mod cols)
    let mut prod = vec![Complex::ZERO; n];
    for r in 0..rows {
        let nr = if r == 0 { 0 } else { rows - r };
        for c in 0..cols {
            let nc = if c == 0 { 0 } else { cols - c };
            let zk = z[r * cols + c];
            let zmk = z[nr * cols + nc].conj();
            // (zk² − zmk²) / (4i)  ==  multiply by  -i/4
            let d = zk * zk - zmk * zmk;
            prod[r * cols + c] = Complex::new(d.im * 0.25, -d.re * 0.25);
        }
    }
    fft2(&mut prod, rows, cols, Direction::Inverse);
    prod.into_iter().map(|c| c.re).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn naive_dft(x: &[Complex], dir: Direction) -> Vec<Complex> {
        let n = x.len();
        let sign = if dir == Direction::Forward { -1.0 } else { 1.0 };
        let mut out = vec![Complex::ZERO; n];
        for (k, o) in out.iter_mut().enumerate() {
            let mut acc = Complex::ZERO;
            for (j, &v) in x.iter().enumerate() {
                let ang = sign * 2.0 * std::f64::consts::PI * (k * j) as f64 / n as f64;
                acc = acc + v * Complex::from_polar(1.0, ang);
            }
            *o = if dir == Direction::Inverse { acc.scale(1.0 / n as f64) } else { acc };
        }
        out
    }

    fn rand_signal(n: usize, seed: u64) -> Vec<Complex> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| Complex::new(rng.normal(), rng.normal())).collect()
    }

    fn assert_close(a: &[Complex], b: &[Complex], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert!(
                (*x - *y).abs() < tol,
                "mismatch at {i}: {x:?} vs {y:?} (|Δ|={})",
                (*x - *y).abs()
            );
        }
    }

    #[test]
    fn radix2_matches_naive_dft() {
        for &n in &[1usize, 2, 4, 8, 16, 64, 128] {
            let x = rand_signal(n, n as u64);
            let mut got = x.clone();
            fft(&mut got);
            let want = naive_dft(&x, Direction::Forward);
            assert_close(&got, &want, 1e-9 * (n as f64 + 1.0));
        }
    }

    #[test]
    fn bluestein_matches_naive_dft() {
        for &n in &[3usize, 5, 6, 7, 10, 12, 15, 33, 100] {
            let x = rand_signal(n, 1000 + n as u64);
            let mut got = x.clone();
            fft(&mut got);
            let want = naive_dft(&x, Direction::Forward);
            assert_close(&got, &want, 1e-8 * (n as f64 + 1.0));
        }
    }

    #[test]
    fn inverse_roundtrip_all_sizes() {
        for &n in &[1usize, 2, 3, 5, 8, 12, 17, 64, 100, 127] {
            let x = rand_signal(n, 7 + n as u64);
            let mut buf = x.clone();
            fft(&mut buf);
            ifft(&mut buf);
            assert_close(&buf, &x, 1e-9 * (n as f64 + 1.0));
        }
    }

    #[test]
    fn fft2_roundtrip() {
        for &(r, c) in &[(4usize, 4usize), (3, 5), (8, 6), (10, 10), (1, 7)] {
            let x = rand_signal(r * c, (r * 31 + c) as u64);
            let mut buf = x.clone();
            fft2(&mut buf, r, c, Direction::Forward);
            fft2(&mut buf, r, c, Direction::Inverse);
            assert_close(&buf, &x, 1e-9 * ((r * c) as f64 + 1.0));
        }
    }

    #[test]
    fn fft2_matches_row_col_naive() {
        let (r, c) = (3usize, 4usize);
        let x = rand_signal(r * c, 77);
        let mut got = x.clone();
        fft2(&mut got, r, c, Direction::Forward);
        // naive: DFT rows then columns
        let mut want = x.clone();
        for i in 0..r {
            let row = naive_dft(&want[i * c..(i + 1) * c], Direction::Forward);
            want[i * c..(i + 1) * c].copy_from_slice(&row);
        }
        for j in 0..c {
            let col: Vec<Complex> = (0..r).map(|i| want[i * c + j]).collect();
            let colf = naive_dft(&col, Direction::Forward);
            for i in 0..r {
                want[i * c + j] = colf[i];
            }
        }
        assert_close(&got, &want, 1e-9 * 13.0);
    }

    #[test]
    fn circular_convolution_matches_direct() {
        let mut rng = Pcg64::new(5);
        for &n in &[4usize, 7, 16, 30] {
            let a = rng.normal_vec(n);
            let b = rng.normal_vec(n);
            let got = circular_convolve(&a, &b);
            for k in 0..n {
                let mut want = 0.0;
                for i in 0..n {
                    want += a[i] * b[(k + n - i) % n];
                }
                assert!((got[k] - want).abs() < 1e-9, "n={n} k={k}: {} vs {want}", got[k]);
            }
        }
    }

    #[test]
    fn circular_convolution2_matches_direct() {
        let mut rng = Pcg64::new(6);
        let (r, c) = (5usize, 6usize);
        let a = rng.normal_vec(r * c);
        let b = rng.normal_vec(r * c);
        let got = circular_convolve2(&a, &b, r, c);
        for kr in 0..r {
            for kc in 0..c {
                let mut want = 0.0;
                for i in 0..r {
                    for j in 0..c {
                        want += a[i * c + j] * b[((kr + r - i) % r) * c + (kc + c - j) % c];
                    }
                }
                let g = got[kr * c + kc];
                assert!((g - want).abs() < 1e-9, "({kr},{kc}): {g} vs {want}");
            }
        }
    }

    #[test]
    fn parseval_energy_preserved() {
        let n = 64;
        let x = rand_signal(n, 3);
        let mut f = x.clone();
        fft(&mut f);
        let ex: f64 = x.iter().map(|c| c.norm_sq()).sum();
        let ef: f64 = f.iter().map(|c| c.norm_sq()).sum::<f64>() / n as f64;
        assert!((ex - ef).abs() < 1e-8 * ex.max(1.0));
    }

    #[test]
    fn plan_cache_reuses_plans() {
        let p1 = plan(48);
        let p2 = plan(48);
        assert!(Rc::ptr_eq(&p1, &p2));
    }
}
