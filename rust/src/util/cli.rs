//! Tiny CLI argument parser (clap is not in the offline crate set).
//!
//! Grammar: `hocs <subcommand> [--flag] [--key value] [positional…]`.
//! Supports `--key=value` and `--key value`, repeated keys (last wins),
//! and typed getters with defaults.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse from the real process arguments.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    /// Comma-separated list of usizes, e.g. `--dims 16,32,64`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name} expects ints, got {p:?}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse("bench fig8 extra");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["fig8", "extra"]);
    }

    #[test]
    fn options_both_syntaxes() {
        let a = parse("run --n 16 --ratio=2.5 --verbose");
        assert_eq!(a.get_usize("n", 0), 16);
        assert_eq!(a.get_f64("ratio", 0.0), 2.5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_str("name", "x"), "x");
    }

    #[test]
    fn list_parsing() {
        let a = parse("bench --dims 2,4,8");
        assert_eq!(a.get_usize_list("dims", &[]), vec![2, 4, 8]);
        assert_eq!(a.get_usize_list("other", &[1]), vec![1]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --a --b val --c");
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("val"));
        assert!(a.flag("c"));
    }

    #[test]
    #[should_panic(expected = "expects an integer")]
    fn typed_getter_panics_on_garbage() {
        parse("x --n abc").get_usize("n", 0);
    }
}
