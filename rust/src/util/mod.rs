//! Cross-cutting utilities built from scratch (the offline crate set has
//! no serde/clap/criterion/proptest): JSON, CLI parsing, a
//! criterion-style micro-benchmark harness, a property-testing
//! mini-framework, and a leveled logger.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logger;
pub mod prop;
pub mod stats;
