//! Property-testing mini-framework (proptest is not in the offline crate
//! set). Provides seeded random case generation, a fixed number of
//! cases per property, and greedy shrinking for integer-vector inputs.
//!
//! Usage:
//! ```
//! use hocs::util::prop::{forall, prop_assert, Gen};
//! forall("sum is commutative", 64, |g: &mut Gen| {
//!     let a = g.f64_in(-10.0, 10.0);
//!     let b = g.f64_in(-10.0, 10.0);
//!     prop_assert(((a + b) - (b + a)).abs() < 1e-12, "commutativity")
//! });
//! ```

use crate::rng::Pcg64;

/// Case generator handed to each property invocation.
pub struct Gen {
    rng: Pcg64,
    /// log of generated values, for failure reporting
    pub trace: Vec<String>,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Pcg64::new(seed), trace: Vec::new() }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let v = lo + self.rng.gen_range((hi - lo + 1) as u64) as usize;
        self.trace.push(format!("usize {v}"));
        v
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.uniform_in(lo, hi);
        self.trace.push(format!("f64 {v:.6}"));
        v
    }

    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        let v = self.rng.normal_vec(n);
        self.trace.push(format!("normal_vec len={n}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let b = self.rng.next_u64() & 1 == 1;
        self.trace.push(format!("bool {b}"));
        b
    }

    /// Random tensor shape: `order` modes each in `[1, max_dim]`.
    pub fn shape(&mut self, order: usize, max_dim: usize) -> Vec<usize> {
        let s: Vec<usize> =
            (0..order).map(|_| 1 + self.rng.gen_range(max_dim as u64) as usize).collect();
        self.trace.push(format!("shape {s:?}"));
        s
    }

    /// Access the raw RNG (for building domain objects).
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Outcome of one property case.
pub type PropResult = Result<(), String>;

/// Assert inside a property.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Assert two floats are close.
pub fn prop_close(a: f64, b: f64, tol: f64, what: &str) -> PropResult {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (|Δ|={}, tol={tol})", (a - b).abs()))
    }
}

/// Run `cases` random cases of `prop`. Panics with the seed + generated
/// value trace of the first failing case so it can be replayed.
pub fn forall(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    forall_seeded(name, cases, 0xF0CA_CC1A, &mut prop);
}

/// [`forall`] with an explicit root seed (replay a failure).
pub fn forall_seeded(
    name: &str,
    cases: usize,
    root_seed: u64,
    prop: &mut impl FnMut(&mut Gen) -> PropResult,
) {
    let mut seeder = Pcg64::new(root_seed);
    for case in 0..cases {
        let seed = seeder.next_u64();
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}):\n  {msg}\n  \
                 generated: [{}]",
                g.trace.join(", ")
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("tautology", 50, |g| {
            count += 1;
            let x = g.f64_in(0.0, 1.0);
            prop_assert((0.0..1.0).contains(&x), "in range")
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn failing_property_panics_with_trace() {
        forall("must fail", 10, |g| {
            let x = g.usize_in(0, 100);
            prop_assert(x < 101, "bound")?;
            prop_assert(false, "always fails")
        });
    }

    #[test]
    fn shapes_respect_bounds() {
        forall("shape bounds", 40, |g| {
            let s = g.shape(3, 7);
            prop_assert(s.len() == 3 && s.iter().all(|&d| (1..=7).contains(&d)), "shape in range")
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut first: Vec<f64> = Vec::new();
        forall_seeded("collect", 5, 42, &mut |g| {
            first.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        let mut second: Vec<f64> = Vec::new();
        forall_seeded("collect", 5, 42, &mut |g| {
            second.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
