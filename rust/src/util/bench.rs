//! Criterion-style micro-benchmark harness (criterion itself is not in
//! the offline crate set). Provides warm-up, adaptive iteration counts,
//! and robust statistics (median / MAD / mean / p10 / p90), plus a
//! column-aligned table printer used by every paper-table bench.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub p10: Duration,
    pub p90: Duration,
    /// median absolute deviation — robust spread estimate
    pub mad: Duration,
}

impl BenchResult {
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// minimum wall-clock spent measuring (after warmup)
    pub measure_time: Duration,
    /// warmup wall-clock
    pub warmup_time: Duration,
    /// hard cap on sample count
    pub max_samples: usize,
    /// minimum samples regardless of time
    pub min_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            measure_time: Duration::from_millis(300),
            warmup_time: Duration::from_millis(60),
            max_samples: 2_000,
            min_samples: 5,
        }
    }
}

impl BenchConfig {
    /// A faster profile for CI / tests.
    pub fn quick() -> Self {
        Self {
            measure_time: Duration::from_millis(40),
            warmup_time: Duration::from_millis(5),
            max_samples: 200,
            min_samples: 3,
        }
    }
}

/// Time `f`, preventing the optimizer from discarding its result.
///
/// `f` should return something cheap to move; use [`black_box`] inside
/// for intermediate values.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    // warmup
    let warm_start = Instant::now();
    while warm_start.elapsed() < cfg.warmup_time {
        black_box(f());
    }
    // measure
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while (start.elapsed() < cfg.measure_time || samples.len() < cfg.min_samples)
        && samples.len() < cfg.max_samples
    {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
    }
    summarize(name, samples)
}

fn summarize(name: &str, mut samples: Vec<Duration>) -> BenchResult {
    assert!(!samples.is_empty());
    samples.sort_unstable();
    let n = samples.len();
    let pct = |p: f64| samples[((n as f64 - 1.0) * p).round() as usize];
    let median = pct(0.5);
    let mean = samples.iter().sum::<Duration>() / n as u32;
    let mut devs: Vec<Duration> = samples
        .iter()
        .map(|&s| if s > median { s - median } else { median - s })
        .collect();
    devs.sort_unstable();
    BenchResult {
        name: name.to_string(),
        iters: n,
        median,
        mean,
        p10: pct(0.1),
        p90: pct(0.9),
        mad: devs[(n - 1) / 2],
    }
}

/// An `std::hint::black_box` stand-in that works on stable.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Human-friendly duration formatting (ns/µs/ms/s with 3 significant
/// figures).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// A column-aligned plain-text table, printed by the paper-table benches.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths.iter())
                .map(|(c, &w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let cfg = BenchConfig::quick();
        let r = bench("spin", &cfg, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.iters >= cfg.min_samples);
        assert!(r.median.as_nanos() > 0);
        assert!(r.p10 <= r.median && r.median <= r.p90);
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_duration(Duration::from_micros(15)).contains("µs"));
        assert!(fmt_duration(Duration::from_millis(15)).contains("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with(" s"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["op", "time"]);
        t.row(vec!["kron".into(), "1.2 ms".into()]);
        t.row(vec!["mts-combine-long".into(), "0.3 ms".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("mts-combine-long"));
        // header padded to widest cell
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[1].starts_with("op "));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
