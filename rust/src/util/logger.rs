//! Leveled stderr logger with wall-clock offsets. Intentionally tiny:
//! the coordinator and trainer want timestamped progress lines, nothing
//! more.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    let tag = match level {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    eprintln!("[{t:9.3}s {tag}] {msg}");
}

#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Error, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
