//! Minimal JSON parser/serializer.
//!
//! Used for the `artifacts/manifest.json` handshake between the Python
//! AOT pipeline and the Rust runtime, and for benchmark result dumps.
//! Supports the full JSON grammar except `\u` surrogate pairs are passed
//! through unvalidated.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------- typed accessors ----------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `[usize]` from a JSON array of numbers.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---------- constructors ----------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---------- serialization ----------
    // (compact form via `Display`, so `.to_string()` comes from the
    // blanket `ToString` impl)

    /// Pretty-print with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

impl std::fmt::Display for Json {
    /// Compact single-line serialization.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns a descriptive error with byte offset.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos.saturating_sub(1)))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("eof in \\u escape")? as char;
                            code = code * 16 + c.to_digit(16).ok_or("bad hex in \\u escape")?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy continuation bytes verbatim
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump().ok_or("eof in utf8 sequence")?;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                other => return Err(format!("expected ',' or ']' got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut o = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            o.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(o)),
                other => return Err(format!("expected ',' or '}}' got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[1].as_f64().unwrap(), 2.0);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"dims":[2,3,4],"name":"model","nested":{"x":1.5},"ok":true}"#;
        let j = parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(parse(&out).unwrap(), j);
    }

    #[test]
    fn roundtrip_pretty() {
        let j = Json::obj(vec![
            ("dims", Json::arr_usize(&[2, 3])),
            ("loss", Json::Num(0.125)),
            ("tag", Json::Str("e2e".into())),
        ]);
        let pretty = j.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), j);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn errors_have_positions() {
        assert!(parse("[1, 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("[] trailing").unwrap_err().contains("trailing"));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let j = Json::Str("tab\t quote\" slash\\ nl\n".into());
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn unicode_passthrough() {
        let j = parse("\"héllo ∘ ⊗\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo ∘ ⊗");
    }

    #[test]
    fn usize_vec_helper() {
        let j = parse("[1, 2, 3]").unwrap();
        assert_eq!(j.as_usize_vec().unwrap(), vec![1, 2, 3]);
        assert!(parse("[1.5]").unwrap().as_usize_vec().is_none());
    }
}
