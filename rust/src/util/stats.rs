//! Small statistics helpers shared by the sketch estimators (median-of-d)
//! and the benchmark/experiment reporting.

/// Median of a slice (does not require sorted input; copies).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "median of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// In-place selection-based median for the hot decode path: O(n) average,
/// reorders `xs`.
pub fn median_inplace(xs: &mut [f64]) -> f64 {
    assert!(!xs.is_empty());
    let n = xs.len();
    let mid = n / 2;
    let (_, m, _) = select_nth(xs, mid);
    if n % 2 == 1 {
        m
    } else {
        // need max of lower half too
        let lower_max = xs[..mid].iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        0.5 * (lower_max + m)
    }
}

fn select_nth(xs: &mut [f64], nth: usize) -> (&mut [f64], f64, &mut [f64]) {
    let (lo, pivot, hi) =
        xs.select_nth_unstable_by(nth, |a, b| a.partial_cmp(b).unwrap());
    (lo, *pivot, hi)
}

pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn variance(xs: &[f64]) -> f64 {
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Sample Pearson correlation.
pub fn correlation(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys.iter()) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    num / (dx.sqrt() * dy.sqrt()).max(f64::MIN_POSITIVE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
        assert_eq!(median(&[5.0]), 5.0);
    }

    #[test]
    fn median_inplace_matches_sort_median() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::new(1);
        for n in 1..40 {
            let xs = rng.normal_vec(n);
            let want = median(&xs);
            let mut buf = xs.clone();
            let got = median_inplace(&mut buf);
            assert!((got - want).abs() < 1e-12, "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn variance_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((variance(&xs) - 4.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn correlation_extremes() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((correlation(&xs, &ys) - 1.0).abs() < 1e-12);
        let zs = [8.0, 6.0, 4.0, 2.0];
        assert!((correlation(&xs, &zs) + 1.0).abs() < 1e-12);
    }
}
