//! Fused SIMD sketch kernels: a lane-parallel hash phase feeding a
//! cache-blocked counter apply.
//!
//! Every byte the store ingests funnels through the fused batch /
//! fan-out walks of [`crate::sketch::stream::StreamSketch`] and the
//! tensor plane's `HcsStream` — previously scalar loops that evaluated
//! two multiply-shift hashes with a hardware divide and a
//! data-dependent sign branch per (item, repeat), then issued one
//! scattered f64 add. This module restructures that walk into two
//! phases:
//!
//! 1. **Hash phase** — per repeat, multiply-shift `h`/`s` are evaluated
//!    on `u64×8` chunks (`LANES`; explicit remainder lanes) into flat
//!    `(bucket, signed_w)` runs. Three strength reductions, all exact:
//!    only the *high* limb of `(a·x + b) mod 2^128` is tracked (plus
//!    the low limb's carry — `MsLimbs::hi`), `% m` goes through the
//!    precomputed `ModReduce` reciprocal instead of a divide, and the
//!    two mode signs combine by XOR-ing their sign bits into the
//!    exponent pattern of `±1.0` instead of branching. The portable
//!    chunked loop is the baseline on every target; on x86-64 with AVX2
//!    and power-of-two table geometry an explicit `std::arch` path
//!    (`avx2` submodule) hashes four lanes per step behind
//!    `is_x86_feature_detected!`, with the portable path as fallback
//!    and the pre-PR scalar walk retained as the oracle.
//! 2. **Apply phase** — the runs are added into the counter table.
//!    Small tables take the scattered loop directly (with software
//!    prefetch a few items ahead once the table outgrows L1); large
//!    tables first stable-partition the runs by bucket *block*
//!    (`RunScratch::stage`) so the scattered writes become block-local
//!    streams — the same-table layering idea of reed-solomon-16's
//!    two-layers-per-pass FFT. Fan-out targets reuse one staged run set
//!    for every table.
//!
//! # Bit-identity
//!
//! The scalar path applies items to each table in batch order. f64
//! addition is order-sensitive, but only *per accumulator*: adds to
//! different buckets touch different counters and commute trivially.
//! The partition in phase 2 is **stable** — items keep their relative
//! order inside a block, and a bucket lives in exactly one block — so
//! every individual counter still receives its contributions in batch
//! order and the resulting tables are bit-identical to the scalar walk.
//! Phase 1 is pure exact integer arithmetic (reductions property-tested
//! against `%` and the reference `eval`), and the sign trick is exact
//! too: `±1.0 · w` rounds nowhere, so `f64::from_bits(ONE | s_i⊕s_j)·w`
//! is the same f64 as `s(i)·s(j)·w`. Every dispatch path therefore
//! emits identical runs; `HOCS_KERNEL=scalar|portable|avx2` forces a
//! path for A/B tests and CI.
//!
//! The ND hash phase additionally memoizes per-(repeat, mode) hashes:
//! when a batch is at least as long as a mode's key range, the mode's
//! `h`/`s` are materialized once via [`ModeHash::bucket_table`] /
//! [`ModeHash::sign_table`] (pre-scaled by the mode stride) and each
//! item does O(order) lookups instead of re-evaluating multiply-shift
//! per repeat.

use crate::hash::{ModReduce, ModeHash, MultiplyShiftHash};
use std::cell::RefCell;
use std::sync::OnceLock;

/// Portable hash-phase lane width (u64 lanes per chunk).
pub(crate) const LANES: usize = 8;

/// Items hashed per tile before the apply phase runs. Bounds the run
/// scratch to ~48 KiB per thread and keeps the runs L1/L2-resident
/// while a tile is staged and applied to (possibly many) tables.
pub(crate) const TILE: usize = 4096;

/// Tables at or below this many counters (256 KiB of f64) are
/// L2-resident; scattered adds are applied directly.
const DIRECT_TABLE_CAP: usize = 1 << 15;

/// Bucket-block size for the stable partition: 4096 counters = 32 KiB,
/// one L1's worth of table per block.
const BLOCK_SHIFT: u32 = 12;
const BLOCK_BUCKETS: usize = 1 << BLOCK_SHIFT;

/// Below this many staged runs the counting-sort pass costs more than
/// the cache misses it saves; fall back to the scattered loop.
const PARTITION_MIN_ITEMS: usize = 512;

/// Scattered-apply prefetch distance (items ahead).
const PREFETCH_AHEAD: usize = 8;

/// Only prefetch when the table exceeds L1 (8192 f64 = 64 KiB); for
/// L1-resident tables the prefetch is pure instruction overhead.
const PREFETCH_MIN_TABLE: usize = 1 << 13;

/// Bit pattern of `+1.0`; OR-ing a sign bit on top yields `±1.0`.
const ONE_BITS: u64 = 0x3FF0_0000_0000_0000;

/// f64 sign bit.
const SIGN_BIT: u64 = 1 << 63;

/// `+1.0` when `bit == 0`, `-1.0` when `bit == 1`.
#[inline]
pub(crate) fn sign_from_bit(bit: u64) -> f64 {
    debug_assert!(bit <= 1);
    f64::from_bits(ONE_BITS | (bit << 63))
}

/// Which hash-phase implementation the fused walks run.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum KernelPath {
    /// Pre-PR per-item reference walk (bit-identity oracle and bench
    /// baseline).
    Scalar,
    /// Lane-chunked portable kernel; LLVM autovectorizes the chunk
    /// bodies. The default on every target.
    Portable,
    /// Explicit `std::arch` AVX2 hash phase. Requires runtime AVX2 and
    /// power-of-two table geometry per mode; other geometries fall back
    /// to [`KernelPath::Portable`] lanes tile-by-tile.
    Avx2,
}

static CONFIGURED: OnceLock<KernelPath> = OnceLock::new();

/// The process-wide kernel path, resolved once from `HOCS_KERNEL`:
/// `scalar` and `portable` force those paths; `avx2`, `auto`, unset, or
/// anything else resolve to the best vector path the CPU supports.
pub fn configured() -> KernelPath {
    *CONFIGURED.get_or_init(|| {
        let want = match std::env::var("HOCS_KERNEL") {
            Ok(v) => v,
            Err(_) => String::new(),
        };
        match want.as_str() {
            "scalar" => KernelPath::Scalar,
            "portable" => KernelPath::Portable,
            _ => best_vector_path(),
        }
    })
}

/// Best vector path for this CPU: AVX2 when detected at runtime,
/// portable lanes otherwise (including every non-x86 target).
fn best_vector_path() -> KernelPath {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return KernelPath::Avx2;
        }
    }
    KernelPath::Portable
}

/// The 64-bit limbs of one multiply-shift hash, plus the exact
/// high-limb evaluation trick.
///
/// `eval(x) = ((a·x + b) mod 2^128) >> 65` depends only on the *high*
/// limb of `a·x + b`: writing `a = a_hi·2^64 + a_lo`, the high limb is
/// `hi64(a_lo·x) + lo64(a_hi·x) + b_hi + carry(lo64(a_lo·x) + b_lo)`
/// (mod 2^64). The low limb influences the result only through that
/// one carry bit, so a full 128-bit product is never needed:
/// `eval(x) == hi(x) >> 1` and the sign bit is `hi(x) >> 63`.
#[derive(Clone, Copy, Debug)]
pub(crate) struct MsLimbs {
    a_lo: u64,
    a_hi: u64,
    b_lo: u64,
    b_hi: u64,
}

impl MsLimbs {
    pub(crate) fn of(h: &MultiplyShiftHash) -> Self {
        let (a_lo, a_hi, b_lo, b_hi) = h.limbs();
        MsLimbs { a_lo, a_hi, b_lo, b_hi }
    }

    /// High limb of `(a·x + b) mod 2^128`.
    #[inline]
    pub(crate) fn hi(&self, x: u64) -> u64 {
        let p = (self.a_lo as u128).wrapping_mul(x as u128);
        let lo = p as u64;
        let hi = ((p >> 64) as u64).wrapping_add(self.a_hi.wrapping_mul(x));
        let carry = lo.overflowing_add(self.b_lo).1;
        hi.wrapping_add(self.b_hi).wrapping_add(carry as u64)
    }
}

/// Hash-phase state for one repeat of a 2-D (matrix) sketch: the four
/// multiply-shift hashes and the two reducers, flattened to POD so the
/// borrow of the owning sketch can end before tables are written.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Hash2d {
    n1: usize,
    n2: usize,
    row_b: MsLimbs,
    row_s: MsLimbs,
    col_b: MsLimbs,
    col_s: MsLimbs,
    row_red: ModReduce,
    col_red: ModReduce,
    m2: u64,
}

impl Hash2d {
    pub(crate) fn new(row: &ModeHash, col: &ModeHash, m2: usize) -> Self {
        debug_assert_eq!(col.m, m2);
        Hash2d {
            n1: row.n,
            n2: col.n,
            row_b: MsLimbs::of(row.bucket_hash()),
            row_s: MsLimbs::of(row.sign_hash()),
            col_b: MsLimbs::of(col.bucket_hash()),
            col_s: MsLimbs::of(col.sign_hash()),
            row_red: row.reducer(),
            col_red: col.reducer(),
            m2: m2 as u64,
        }
    }

    /// One item: `(bucket, s(i)·s(j)·w)`, bit-identical to the scalar
    /// walk (single-point fan-out uses this directly).
    #[inline]
    pub(crate) fn one(&self, i: usize, j: usize, w: f64) -> (usize, f64) {
        debug_assert!(i < self.n1 && j < self.n2);
        let hr = self.row_red.reduce(self.row_b.hi(i as u64) >> 1);
        let hc = self.col_red.reduce(self.col_b.hi(j as u64) >> 1);
        let sb = (self.row_s.hi(i as u64) ^ self.col_s.hi(j as u64)) & SIGN_BIT;
        ((hr * self.m2 + hc) as usize, f64::from_bits(ONE_BITS | sb) * w)
    }
}

/// Portable lane-chunked hash phase: LANES items per chunk into stack
/// arrays (autovectorizable straight-line bodies), explicit remainder.
fn hash_tile_2d_portable(
    h: &Hash2d,
    items: &[(usize, usize, f64)],
    out_b: &mut Vec<u32>,
    out_v: &mut Vec<f64>,
) {
    out_b.clear();
    out_v.clear();
    out_b.reserve(items.len());
    out_v.reserve(items.len());
    let mut chunks = items.chunks_exact(LANES);
    for c in chunks.by_ref() {
        let mut bl = [0u32; LANES];
        let mut vl = [0.0f64; LANES];
        for (l, &(i, j, w)) in c.iter().enumerate() {
            let (b, v) = h.one(i, j, w);
            bl[l] = b as u32;
            vl[l] = v;
        }
        out_b.extend_from_slice(&bl);
        out_v.extend_from_slice(&vl);
    }
    for &(i, j, w) in chunks.remainder() {
        let (b, v) = h.one(i, j, w);
        out_b.push(b as u32);
        out_v.push(v);
    }
}

/// Hash phase for one tile of 2-D items on the given path. Buckets are
/// emitted as u32 — callers guarantee `m1·m2 ≤ u32::MAX` (checked at
/// the wiring sites; oversized geometries stay on the scalar walk).
pub(crate) fn hash_tile_2d(
    path: KernelPath,
    h: &Hash2d,
    items: &[(usize, usize, f64)],
    out_b: &mut Vec<u32>,
    out_v: &mut Vec<f64>,
) {
    // dispatch counters record the branch actually taken (per tile,
    // not per element — negligible against the tile's hash work)
    match path {
        KernelPath::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            if h.row_red.pow2_mask().is_some() && h.col_red.pow2_mask().is_some() {
                // SAFETY: `Avx2` is only configured after
                // `is_x86_feature_detected!("avx2")` succeeded, and the
                // guard pins the pow2 geometry the AVX2 tile requires.
                unsafe { avx2::hash_tile(h, items, out_b, out_v) };
                crate::obs::global().kernel_avx2.inc();
                return;
            }
            crate::obs::global().kernel_portable.inc();
            hash_tile_2d_portable(h, items, out_b, out_v);
        }
        _ => {
            crate::obs::global().kernel_portable.inc();
            hash_tile_2d_portable(h, items, out_b, out_v);
        }
    }
}

/// Explicit AVX2 hash phase: four u64 lanes per step, pow2 geometry.
///
/// 64×64→128 products are assembled from `_mm256_mul_epu32` 32-bit
/// partial products; the `b_lo` carry comes from an unsigned overflow
/// compare (sign-biased `_mm256_cmpgt_epi64`). All integer math —
/// bit-identical to `MsLimbs::hi` by construction.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{Hash2d, MsLimbs, ONE_BITS, SIGN_BIT};
    use core::arch::x86_64::*;

    /// Broadcast a u64 constant.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn splat(c: u64) -> __m256i {
        _mm256_set1_epi64x(c as i64)
    }

    /// Lane-wise full 64×64→128 product against a scalar constant:
    /// `(lo, hi)` limbs.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn mul_lo_hi(x: __m256i, c: u64) -> (__m256i, __m256i) {
        let mask32 = splat(0xFFFF_FFFF);
        let c_l = splat(c & 0xFFFF_FFFF);
        let c_h = splat(c >> 32);
        let x_h = _mm256_srli_epi64(x, 32);
        let ll = _mm256_mul_epu32(x, c_l);
        let hl = _mm256_mul_epu32(x_h, c_l);
        let lh = _mm256_mul_epu32(x, c_h);
        let hh = _mm256_mul_epu32(x_h, c_h);
        // carries of the two middle partials, via an explicit 32-bit
        // column sum (cannot overflow: three 32-bit terms)
        let cross = _mm256_add_epi64(
            _mm256_add_epi64(_mm256_srli_epi64(ll, 32), _mm256_and_si256(hl, mask32)),
            _mm256_and_si256(lh, mask32),
        );
        let hi = _mm256_add_epi64(
            _mm256_add_epi64(hh, _mm256_srli_epi64(hl, 32)),
            _mm256_add_epi64(_mm256_srli_epi64(lh, 32), _mm256_srli_epi64(cross, 32)),
        );
        let lo = _mm256_add_epi64(ll, _mm256_slli_epi64(_mm256_add_epi64(hl, lh), 32));
        (lo, hi)
    }

    /// Lane-wise low 64 bits of `x · c` (wrapping).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn mul_lo(x: __m256i, c: u64) -> __m256i {
        let c_l = splat(c & 0xFFFF_FFFF);
        let c_h = splat(c >> 32);
        let x_h = _mm256_srli_epi64(x, 32);
        let ll = _mm256_mul_epu32(x, c_l);
        let hl = _mm256_mul_epu32(x_h, c_l);
        let lh = _mm256_mul_epu32(x, c_h);
        _mm256_add_epi64(ll, _mm256_slli_epi64(_mm256_add_epi64(hl, lh), 32))
    }

    /// Lane-wise `MsLimbs::hi`: high limb of `a·x + b`.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn ms_hi(x: __m256i, l: MsLimbs) -> __m256i {
        let (p_lo, p_hi) = mul_lo_hi(x, l.a_lo);
        let hi = _mm256_add_epi64(p_hi, mul_lo(x, l.a_hi));
        let sum = _mm256_add_epi64(p_lo, splat(l.b_lo));
        // unsigned `sum < p_lo` (i.e. the add carried) via sign-biased
        // signed compare; a carry lane is all-ones == -1, so subtract
        let bias = splat(1 << 63);
        let carry = _mm256_cmpgt_epi64(_mm256_xor_si256(p_lo, bias), _mm256_xor_si256(sum, bias));
        _mm256_sub_epi64(_mm256_add_epi64(hi, splat(l.b_hi)), carry)
    }

    /// AVX2 hash phase for one tile. Remainder lanes (< 4 items) take
    /// the scalar `Hash2d::one`, which computes the identical bits.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2 and that both of `h`'s
    /// reducers are pow2 masks.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn hash_tile(
        h: &Hash2d,
        items: &[(usize, usize, f64)],
        out_b: &mut Vec<u32>,
        out_v: &mut Vec<f64>,
    ) {
        out_b.clear();
        out_v.clear();
        out_b.reserve(items.len());
        out_v.reserve(items.len());
        let row_mask = h.row_red.pow2_mask().expect("avx2 path requires pow2 m1");
        let col_mask = h.col_red.pow2_mask().expect("avx2 path requires pow2 m2");
        debug_assert_eq!(col_mask + 1, h.m2);
        let rm = splat(row_mask);
        let cm = splat(col_mask);
        let sign = splat(SIGN_BIT);
        let one = splat(ONE_BITS);
        // bucket = (er & rm) · m2 + (ec & cm) == (er & rm) << log2(m2) | ec
        let m2_shift = _mm_cvtsi64_si128((col_mask + 1).trailing_zeros() as i64);
        let mut chunks = items.chunks_exact(4);
        for c in chunks.by_ref() {
            let xi = _mm256_set_epi64x(c[3].0 as i64, c[2].0 as i64, c[1].0 as i64, c[0].0 as i64);
            let xj = _mm256_set_epi64x(c[3].1 as i64, c[2].1 as i64, c[1].1 as i64, c[0].1 as i64);
            let wv = _mm256_set_pd(c[3].2, c[2].2, c[1].2, c[0].2);
            let er = _mm256_and_si256(_mm256_srli_epi64(ms_hi(xi, h.row_b), 1), rm);
            let ec = _mm256_and_si256(_mm256_srli_epi64(ms_hi(xj, h.col_b), 1), cm);
            let b = _mm256_or_si256(_mm256_sll_epi64(er, m2_shift), ec);
            let sr = ms_hi(xi, h.row_s);
            let sc = ms_hi(xj, h.col_s);
            let sb = _mm256_and_si256(_mm256_xor_si256(sr, sc), sign);
            let vv = _mm256_mul_pd(_mm256_castsi256_pd(_mm256_or_si256(sb, one)), wv);
            let mut bl = [0u64; 4];
            let mut vl = [0.0f64; 4];
            _mm256_storeu_si256(bl.as_mut_ptr() as *mut __m256i, b);
            _mm256_storeu_pd(vl.as_mut_ptr(), vv);
            out_b.extend_from_slice(&[bl[0] as u32, bl[1] as u32, bl[2] as u32, bl[3] as u32]);
            out_v.extend_from_slice(&vl);
        }
        for &(i, j, w) in chunks.remainder() {
            let (b, v) = h.one(i, j, w);
            out_b.push(b as u32);
            out_v.push(v);
        }
    }
}

/// One mode of an ND hash phase: either a memoized `(h·stride, s)`
/// lookup table (built when the batch is long enough to amortize it)
/// or the direct multiply-shift limbs.
pub(crate) enum NdMode {
    Table { off: Vec<u32>, sign: Vec<f64> },
    Direct { bucket: MsLimbs, sign: MsLimbs, red: ModReduce, stride: u64, n: usize },
}

/// Hash-phase state for one repeat of an N-mode HCS sketch.
pub(crate) struct HashNd {
    modes: Vec<NdMode>,
}

impl HashNd {
    /// Build repeat state from the per-mode hashes and row-major
    /// strides. A mode is tabulated iff the batch has at least as many
    /// items as the mode's key range `n_k` — one table build then O(1)
    /// lookups beats `batch_len` multiply-shift evaluations. Callers
    /// guarantee `Σ (m_k−1)·stride_k < table_len ≤ u32::MAX`, so the
    /// pre-scaled offsets fit u32.
    pub(crate) fn new(hashes: &[ModeHash], strides: &[usize], batch_len: usize) -> Self {
        debug_assert_eq!(hashes.len(), strides.len());
        let modes = hashes
            .iter()
            .zip(strides.iter())
            .map(|(mh, &stride)| {
                if mh.n <= batch_len {
                    let off = mh.bucket_table().iter().map(|&h| h * stride as u32).collect();
                    NdMode::Table { off, sign: mh.sign_table() }
                } else {
                    NdMode::Direct {
                        bucket: MsLimbs::of(mh.bucket_hash()),
                        sign: MsLimbs::of(mh.sign_hash()),
                        red: mh.reducer(),
                        stride: stride as u64,
                        n: mh.n,
                    }
                }
            })
            .collect();
        HashNd { modes }
    }

    /// One item: `(Σ_k h_k(i_k)·stride_k, Π_k s_k(i_k) · w)`. The sign
    /// product multiplies exact `±1.0` factors in mode order, exactly
    /// like the scalar walk (every intermediate is `±1.0`, so the fold
    /// is bit-identical regardless of path).
    #[inline]
    pub(crate) fn one(&self, key: &[usize], w: f64) -> (usize, f64) {
        debug_assert_eq!(key.len(), self.modes.len());
        let mut b = 0u64;
        let mut s = 1.0f64;
        for (mode, &i) in self.modes.iter().zip(key.iter()) {
            match mode {
                NdMode::Table { off, sign } => {
                    b += off[i] as u64;
                    s *= sign[i];
                }
                NdMode::Direct { bucket, sign, red, stride, n } => {
                    debug_assert!(i < *n);
                    b += red.reduce(bucket.hi(i as u64) >> 1) * stride;
                    s *= f64::from_bits(ONE_BITS | (sign.hi(i as u64) & SIGN_BIT));
                }
            }
        }
        (b as usize, s * w)
    }
}

/// ND hash phase for one tile: `keys` is a flat `[order·len]` index
/// array zipped with `ws`.
pub(crate) fn hash_tile_nd(
    h: &HashNd,
    order: usize,
    keys: &[usize],
    ws: &[f64],
    out_b: &mut Vec<u32>,
    out_v: &mut Vec<f64>,
) {
    out_b.clear();
    out_v.clear();
    out_b.reserve(ws.len());
    out_v.reserve(ws.len());
    for (key, &w) in keys.chunks_exact(order).zip(ws.iter()) {
        let (b, v) = h.one(key, w);
        out_b.push(b as u32);
        out_v.push(v);
    }
}

/// Per-thread kernel scratch: hash-phase output runs plus the
/// counting-sort buffers of the apply phase. Steady-state batch ingest
/// allocates nothing once these are warm.
pub(crate) struct RunScratch {
    /// hash-phase output: bucket per item
    pub(crate) b: Vec<u32>,
    /// hash-phase output: signed weight per item
    pub(crate) v: Vec<f64>,
    sorted_b: Vec<u32>,
    sorted_v: Vec<f64>,
    counts: Vec<u32>,
    staged: bool,
}

impl RunScratch {
    fn new() -> Self {
        RunScratch {
            b: Vec::new(),
            v: Vec::new(),
            sorted_b: Vec::new(),
            sorted_v: Vec::new(),
            counts: Vec::new(),
            staged: false,
        }
    }

    /// Decide the apply strategy for the runs currently in `b`/`v`
    /// against a table of `table_len` counters, stable-partitioning
    /// them by bucket block when the table is large enough to thrash
    /// cache and the tile is large enough to amortize the two counting
    /// passes. Read the (possibly reordered) runs back via
    /// [`RunScratch::runs`]; fan-out callers stage once and apply the
    /// same runs to every target table.
    pub(crate) fn stage(&mut self, table_len: usize) {
        self.staged = false;
        let n = self.b.len();
        debug_assert_eq!(n, self.v.len());
        if table_len <= DIRECT_TABLE_CAP || n < PARTITION_MIN_ITEMS {
            return;
        }
        let nblocks = table_len.div_ceil(BLOCK_BUCKETS);
        self.counts.clear();
        self.counts.resize(nblocks, 0);
        for &b in &self.b {
            self.counts[(b as usize) >> BLOCK_SHIFT] += 1;
        }
        // exclusive prefix sum: counts become per-block write cursors
        let mut acc = 0u32;
        for c in self.counts.iter_mut() {
            let k = *c;
            *c = acc;
            acc += k;
        }
        self.sorted_b.clear();
        self.sorted_b.resize(n, 0);
        self.sorted_v.clear();
        self.sorted_v.resize(n, 0.0);
        // stable placement: within a block, batch order is preserved,
        // so every counter sees its adds in the scalar order
        for (&b, &v) in self.b.iter().zip(self.v.iter()) {
            let cur = &mut self.counts[(b as usize) >> BLOCK_SHIFT];
            let dst = *cur as usize;
            *cur += 1;
            self.sorted_b[dst] = b;
            self.sorted_v[dst] = v;
        }
        self.staged = true;
    }

    /// The `(bucket, signed_w)` runs to apply — block-partitioned when
    /// [`RunScratch::stage`] decided that pays, batch order otherwise.
    pub(crate) fn runs(&self) -> (&[u32], &[f64]) {
        if self.staged {
            (&self.sorted_b, &self.sorted_v)
        } else {
            (&self.b, &self.v)
        }
    }
}

thread_local! {
    static SCRATCH: RefCell<RunScratch> = RefCell::new(RunScratch::new());
}

/// Run `f` with this thread's kernel scratch. Not reentrant — kernel
/// call sites never nest batch walks.
pub(crate) fn with_scratch<R>(f: impl FnOnce(&mut RunScratch) -> R) -> R {
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Apply phase: add the runs into `table`. Order within the slice is
/// preserved exactly (this is the only place f64 order matters). For
/// tables beyond L1 the scattered loop prefetches a few items ahead;
/// staged (block-partitioned) runs stream through the table mostly in
/// order and the prefetches degenerate to cheap L1 hits.
pub(crate) fn apply_runs(table: &mut [f64], bs: &[u32], vs: &[f64]) {
    debug_assert_eq!(bs.len(), vs.len());
    if table.len() > PREFETCH_MIN_TABLE {
        for (t, (&b, &v)) in bs.iter().zip(vs.iter()).enumerate() {
            prefetch_ahead(table, bs, t);
            table[b as usize] += v;
        }
    } else {
        for (&b, &v) in bs.iter().zip(vs.iter()) {
            table[b as usize] += v;
        }
    }
}

/// Prefetch the counter `PREFETCH_AHEAD` items past position `t` into
/// L1. No-op off x86-64.
#[inline]
#[allow(unused_variables)]
fn prefetch_ahead(table: &[f64], bs: &[u32], t: usize) {
    #[cfg(target_arch = "x86_64")]
    if let Some(&nb) = bs.get(t + PREFETCH_AHEAD) {
        if let Some(slot) = table.get(nb as usize) {
            // SAFETY: prefetch is a hint with no memory effects; the
            // address is a live in-bounds element of `table`.
            unsafe {
                core::arch::x86_64::_mm_prefetch(
                    slot as *const f64 as *const i8,
                    core::arch::x86_64::_MM_HINT_T0,
                )
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    fn ms(seed: u64) -> MultiplyShiftHash {
        let mut sm = SplitMix64::new(seed);
        MultiplyShiftHash::new(&mut sm)
    }

    #[test]
    fn high_limb_trick_matches_reference_eval() {
        for seed in 0..20u64 {
            let h = ms(seed);
            let l = MsLimbs::of(&h);
            let mut sm = SplitMix64::new(seed ^ 0xABCD);
            for x in [0u64, 1, u64::MAX, 1 << 63] {
                assert_eq!(l.hi(x) >> 1, h.eval(x));
            }
            for _ in 0..2000 {
                let x = sm.next_u64();
                assert_eq!(l.hi(x) >> 1, h.eval(x), "seed={seed} x={x}");
                assert_eq!((l.hi(x) >> 63) & 1, (h.eval(x) >> 62) & 1);
            }
        }
    }

    #[test]
    fn hash2d_one_matches_scalar_walk() {
        for (m1, m2, seed) in [(64usize, 64usize, 1u64), (37, 12, 2), (1, 5, 3), (4096, 9, 4)] {
            let row = ModeHash::new(500, m1, seed);
            let col = ModeHash::new(300, m2, seed ^ 0x55);
            let h = Hash2d::new(&row, &col, m2);
            let mut sm = SplitMix64::new(seed);
            for _ in 0..2000 {
                let i = (sm.next_u64() % 500) as usize;
                let j = (sm.next_u64() % 300) as usize;
                let w = (sm.next_u64() % 1000) as f64 / 7.0 - 60.0;
                let (b, v) = h.one(i, j, w);
                assert_eq!(b, row.h(i) * m2 + col.h(j));
                assert_eq!(v.to_bits(), (row.s(i) * col.s(j) * w).to_bits());
            }
        }
    }

    fn random_items(n: usize, n1: usize, n2: usize, seed: u64) -> Vec<(usize, usize, f64)> {
        let mut sm = SplitMix64::new(seed);
        (0..n)
            .map(|_| {
                let i = (sm.next_u64() % n1 as u64) as usize;
                let j = (sm.next_u64() % n2 as u64) as usize;
                // mixed signs incl. deletions so ordering bugs show
                let w = ((sm.next_u64() % 2000) as f64 - 1000.0) * 0.125;
                (i, j, w)
            })
            .collect()
    }

    #[test]
    fn portable_tile_matches_per_item_walk() {
        let row = ModeHash::new(1000, 37, 5);
        let col = ModeHash::new(800, 64, 6);
        let h = Hash2d::new(&row, &col, 64);
        for n in [0usize, 1, LANES - 1, LANES, LANES + 1, 1000] {
            let items = random_items(n, 1000, 800, n as u64 + 9);
            let mut bs = Vec::new();
            let mut vs = Vec::new();
            hash_tile_2d(KernelPath::Portable, &h, &items, &mut bs, &mut vs);
            assert_eq!(bs.len(), n);
            for (t, &(i, j, w)) in items.iter().enumerate() {
                let (b, v) = h.one(i, j, w);
                assert_eq!(bs[t] as usize, b);
                assert_eq!(vs[t].to_bits(), v.to_bits());
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_tile_matches_portable_lanes() {
        if !is_x86_feature_detected!("avx2") {
            return;
        }
        let row = ModeHash::new(4096, 64, 7);
        let col = ModeHash::new(4096, 128, 8);
        let h = Hash2d::new(&row, &col, 128);
        for n in [0usize, 1, 3, 4, 5, 8, 9, 1000] {
            let items = random_items(n, 4096, 4096, n as u64 + 21);
            let (mut pb, mut pv) = (Vec::new(), Vec::new());
            let (mut ab, mut av) = (Vec::new(), Vec::new());
            hash_tile_2d(KernelPath::Portable, &h, &items, &mut pb, &mut pv);
            hash_tile_2d(KernelPath::Avx2, &h, &items, &mut ab, &mut av);
            assert_eq!(pb, ab, "buckets diverge at n={n}");
            let pvb: Vec<u64> = pv.iter().map(|v| v.to_bits()).collect();
            let avb: Vec<u64> = av.iter().map(|v| v.to_bits()).collect();
            assert_eq!(pvb, avb, "values diverge at n={n}");
        }
    }

    #[test]
    fn avx2_path_falls_back_on_non_pow2_geometry() {
        // m1 = 37 is not a power of two: the Avx2 path must produce the
        // portable (== scalar) bits via fallback, never garbage
        let row = ModeHash::new(512, 37, 9);
        let col = ModeHash::new(512, 64, 10);
        let h = Hash2d::new(&row, &col, 64);
        let items = random_items(333, 512, 512, 11);
        let (mut pb, mut pv) = (Vec::new(), Vec::new());
        let (mut ab, mut av) = (Vec::new(), Vec::new());
        hash_tile_2d(KernelPath::Portable, &h, &items, &mut pb, &mut pv);
        hash_tile_2d(KernelPath::Avx2, &h, &items, &mut ab, &mut av);
        assert_eq!(pb, ab);
        let pvb: Vec<u64> = pv.iter().map(|v| v.to_bits()).collect();
        let avb: Vec<u64> = av.iter().map(|v| v.to_bits()).collect();
        assert_eq!(pvb, avb);
    }

    #[test]
    fn staged_apply_bit_identical_to_batch_order() {
        let table_len = DIRECT_TABLE_CAP * 4;
        let n = PARTITION_MIN_ITEMS * 3 + 13;
        let mut sm = SplitMix64::new(77);
        // heavy collisions spread across blocks, mixed magnitudes so
        // any reorder of a bucket's adds changes the bits
        let bs: Vec<u32> = (0..n)
            .map(|_| ((sm.next_u64() % 1024) * (table_len as u64 / 1024)) as u32)
            .collect();
        let vs: Vec<f64> = (0..n)
            .map(|_| {
                let mag = 10f64.powi((sm.next_u64() % 9) as i32 - 4);
                ((sm.next_u64() % 1000) as f64 - 500.0) * mag
            })
            .collect();
        let mut direct = vec![0.0f64; table_len];
        for (&b, &v) in bs.iter().zip(vs.iter()) {
            direct[b as usize] += v;
        }
        let mut staged = vec![0.0f64; table_len];
        with_scratch(|s| {
            s.b.clear();
            s.v.clear();
            s.b.extend_from_slice(&bs);
            s.v.extend_from_slice(&vs);
            s.stage(table_len);
            assert!(s.staged, "partition should engage for this size");
            let (pb, pv) = s.runs();
            assert_eq!(pb.len(), n);
            apply_runs(&mut staged, pb, pv);
        });
        for (t, (a, b)) in direct.iter().zip(staged.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "counter {t} diverges");
        }
    }

    #[test]
    fn small_stage_stays_in_batch_order() {
        with_scratch(|s| {
            s.b.clear();
            s.v.clear();
            s.b.extend_from_slice(&[5, 1, 5]);
            s.v.extend_from_slice(&[1.0, 2.0, 3.0]);
            s.stage(64);
            assert!(!s.staged);
            let (pb, pv) = s.runs();
            assert_eq!(pb, &[5u32, 1, 5][..]);
            assert_eq!(pv, &[1.0f64, 2.0, 3.0][..]);
        });
    }

    #[test]
    fn hash_nd_matches_scalar_reference_in_all_modes() {
        let dims = [16usize, 12, 10];
        let mdims = [6usize, 5, 4];
        let strides = [20usize, 4, 1];
        let hashes: Vec<ModeHash> = dims
            .iter()
            .zip(mdims.iter())
            .enumerate()
            .map(|(k, (&n, &m))| ModeHash::new(n, m, 31 + k as u64))
            .collect();
        // batch_len 0 → all Direct; 11 → mixed; 1000 → all Table
        for batch_len in [0usize, 11, 1000] {
            let h = HashNd::new(&hashes, &strides, batch_len);
            let mut sm = SplitMix64::new(batch_len as u64 + 3);
            for _ in 0..500 {
                let key: Vec<usize> =
                    dims.iter().map(|&n| (sm.next_u64() % n as u64) as usize).collect();
                let w = (sm.next_u64() % 100) as f64 / 3.0 - 16.0;
                let mut eb = 0usize;
                let mut es = 1.0f64;
                for (k, &i) in key.iter().enumerate() {
                    eb += hashes[k].h(i) * strides[k];
                    es *= hashes[k].s(i);
                }
                let (b, v) = h.one(&key, w);
                assert_eq!(b, eb, "batch_len={batch_len}");
                assert_eq!(v.to_bits(), (es * w).to_bits(), "batch_len={batch_len}");
            }
        }
    }

    #[test]
    fn hash_tile_nd_flattens_keys() {
        let hashes = vec![ModeHash::new(8, 4, 1), ModeHash::new(8, 4, 2)];
        let strides = [4usize, 1];
        let h = HashNd::new(&hashes, &strides, 100);
        let keys = [0usize, 1, 2, 3, 7, 7];
        let ws = [1.5f64, -2.5, 4.0];
        let mut bs = Vec::new();
        let mut vs = Vec::new();
        hash_tile_nd(&h, 2, &keys, &ws, &mut bs, &mut vs);
        assert_eq!(bs.len(), 3);
        for (t, (key, &w)) in keys.chunks_exact(2).zip(ws.iter()).enumerate() {
            let (b, v) = h.one(key, w);
            assert_eq!(bs[t] as usize, b);
            assert_eq!(vs[t].to_bits(), v.to_bits());
        }
    }

    #[test]
    fn sign_from_bit_is_exact() {
        assert_eq!(sign_from_bit(0).to_bits(), 1.0f64.to_bits());
        assert_eq!(sign_from_bit(1).to_bits(), (-1.0f64).to_bits());
    }

    #[test]
    fn configured_resolves_to_some_path() {
        // can't force the env here (process-wide OnceLock; CI runs the
        // suite under each HOCS_KERNEL value) — just pin the contract
        // that dispatch resolves and is stable
        assert_eq!(configured(), configured());
    }
}
