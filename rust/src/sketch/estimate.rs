//! Median-of-d robust estimation (§2.2: "the estimation can be made more
//! robust by taking d independent sketches … and calculate the median of
//! the d estimators"; the Chebyshev + median amplification step of every
//! recovery theorem in the paper).

use crate::tensor::Tensor;
use crate::util::stats::median_inplace;

/// Median of `d` scalar estimates produced by `f(rep)`.
pub fn median_of_d(d: usize, mut f: impl FnMut(usize) -> f64) -> f64 {
    assert!(d > 0);
    let mut xs: Vec<f64> = (0..d).map(&mut f).collect();
    median_inplace(&mut xs)
}

/// Entry-wise median of `d` full decompressions produced by `f(rep)`.
pub fn median_decompress(d: usize, mut f: impl FnMut(usize) -> Tensor) -> Tensor {
    assert!(d > 0);
    let first = f(0);
    let dims = first.dims().to_vec();
    let len = first.len();
    let mut cols: Vec<Vec<f64>> = vec![Vec::with_capacity(d); len];
    for (i, &v) in first.data().iter().enumerate() {
        cols[i].push(v);
    }
    for rep in 1..d {
        let t = f(rep);
        assert_eq!(t.dims(), dims.as_slice(), "decompression {rep} changed shape");
        for (i, &v) in t.data().iter().enumerate() {
            cols[i].push(v);
        }
    }
    let data: Vec<f64> = cols.iter_mut().map(|c| median_inplace(c)).collect();
    Tensor::from_vec(data, &dims)
}

/// Number of repeats the theory asks for to achieve failure probability
/// δ: d = Ω(log(1/δ)). A concrete constant: ⌈4.5 · ln(1/δ)⌉, made odd.
pub fn repeats_for_confidence(delta: f64) -> usize {
    assert!((0.0..1.0).contains(&delta) && delta > 0.0);
    let d = (4.5 * (1.0 / delta).ln()).ceil() as usize;
    if d % 2 == 0 {
        d + 1
    } else {
        d.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::sketch::mts::MtsSketcher;
    use crate::tensor::rel_error;

    #[test]
    fn median_of_d_suppresses_outliers() {
        let vals = [1.0, 1.1, 0.9, 100.0, 1.05];
        let m = median_of_d(5, |i| vals[i]);
        assert!((m - 1.05).abs() < 1e-12);
    }

    #[test]
    fn median_decompress_improves_mts_recovery() {
        let dims = [10usize, 10];
        let mut rng = Pcg64::new(1);
        let t = Tensor::randn(&dims, &mut rng);
        let single = {
            let sk = MtsSketcher::with_repeat(&dims, &[6, 6], 5, 0);
            sk.decompress(&sk.sketch(&t))
        };
        let med = median_decompress(9, |rep| {
            let sk = MtsSketcher::with_repeat(&dims, &[6, 6], 5, rep);
            sk.decompress(&sk.sketch(&t))
        });
        let e1 = rel_error(&t, &single);
        let e9 = rel_error(&t, &med);
        assert!(e9 < e1, "median-of-9 {e9} should beat single {e1}");
    }

    #[test]
    fn repeats_for_confidence_monotone() {
        let d1 = repeats_for_confidence(0.1);
        let d2 = repeats_for_confidence(0.01);
        let d3 = repeats_for_confidence(0.001);
        assert!(d1 <= d2 && d2 <= d3);
        assert!(d1 % 2 == 1 && d2 % 2 == 1 && d3 % 2 == 1);
    }

    #[test]
    #[should_panic]
    fn zero_repeats_panics() {
        median_of_d(0, |_| 0.0);
    }
}
