//! Streaming frequency estimation over a 2-D key space — the intro's
//! motivating application (Demaine et al.: "determine essential features
//! of the traffic stream using limited space"), done with MTS instead of
//! a flat count sketch: keys are (src, dst) pairs and each axis is
//! hashed independently, so the sketch is an m1×m2 matrix that supports
//! row/column marginal queries as well as point queries.
//!
//! Median-of-d across independent hash families gives the usual
//! heavy-hitter guarantees; `heavy_hitters` scans the key space (dense
//! universes) and returns entries whose estimate clears a threshold.

use crate::hash::{HashSeeds, ModeHash};
use crate::util::stats::median_inplace;

/// d independent m1×m2 MTS counters over keys `[n1] × [n2]`.
#[derive(Clone, Debug)]
pub struct StreamSketch {
    pub n1: usize,
    pub n2: usize,
    pub m1: usize,
    pub m2: usize,
    pub d: usize,
    rows: Vec<ModeHash>,
    cols: Vec<ModeHash>,
    tables: Vec<Vec<f64>>,
    /// total updates processed
    pub updates: u64,
}

impl StreamSketch {
    pub fn new(n1: usize, n2: usize, m1: usize, m2: usize, d: usize, seed: u64) -> Self {
        assert!(d >= 1);
        let seeds = HashSeeds::new(seed);
        let rows = (0..d).map(|r| ModeHash::new(n1, m1, seeds.seed_for(r, 0))).collect();
        let cols = (0..d).map(|r| ModeHash::new(n2, m2, seeds.seed_for(r, 1))).collect();
        Self {
            n1,
            n2,
            m1,
            m2,
            d,
            rows,
            cols,
            tables: vec![vec![0.0; m1 * m2]; d],
            updates: 0,
        }
    }

    /// Space used, in f64 counters.
    pub fn space(&self) -> usize {
        self.d * self.m1 * self.m2
    }

    /// Process one stream item: key (i, j) with weight `w` (e.g. bytes).
    pub fn update(&mut self, i: usize, j: usize, w: f64) {
        debug_assert!(i < self.n1 && j < self.n2);
        for r in 0..self.d {
            let b = self.rows[r].h(i) * self.m2 + self.cols[r].h(j);
            self.tables[r][b] += self.rows[r].s(i) * self.cols[r].s(j) * w;
        }
        self.updates += 1;
    }

    /// Point query: median-of-d estimate of the total weight of (i, j).
    pub fn query(&self, i: usize, j: usize) -> f64 {
        let mut est: Vec<f64> = (0..self.d)
            .map(|r| {
                let b = self.rows[r].h(i) * self.m2 + self.cols[r].h(j);
                self.rows[r].s(i) * self.cols[r].s(j) * self.tables[r][b]
            })
            .collect();
        median_inplace(&mut est)
    }

    /// All keys whose estimated weight is ≥ `threshold` (dense scan —
    /// the universe here is the n1×n2 key grid).
    pub fn heavy_hitters(&self, threshold: f64) -> Vec<(usize, usize, f64)> {
        let mut out = Vec::new();
        for i in 0..self.n1 {
            for j in 0..self.n2 {
                let w = self.query(i, j);
                if w >= threshold {
                    out.push((i, j, w));
                }
            }
        }
        out.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn point_queries_track_true_counts() {
        let mut sk = StreamSketch::new(64, 64, 16, 16, 5, 1);
        let mut truth = std::collections::HashMap::new();
        let mut rng = Pcg64::new(2);
        // zipf-ish: a few heavy keys + light noise
        for _ in 0..5000 {
            let (i, j) = if rng.uniform() < 0.5 {
                (3usize, 7usize)
            } else if rng.uniform() < 0.5 {
                (40, 9)
            } else {
                (rng.gen_range(64) as usize, rng.gen_range(64) as usize)
            };
            sk.update(i, j, 1.0);
            *truth.entry((i, j)).or_insert(0.0f64) += 1.0;
        }
        let t1 = truth[&(3, 7)];
        let e1 = sk.query(3, 7);
        assert!((e1 - t1).abs() < 0.15 * t1, "heavy key: {e1} vs {t1}");
        let t2 = truth[&(40, 9)];
        let e2 = sk.query(40, 9);
        assert!((e2 - t2).abs() < 0.15 * t2, "heavy key: {e2} vs {t2}");
    }

    #[test]
    fn heavy_hitters_found_in_order() {
        let mut sk = StreamSketch::new(32, 32, 12, 12, 5, 7);
        for _ in 0..300 {
            sk.update(1, 2, 1.0);
        }
        for _ in 0..150 {
            sk.update(10, 20, 1.0);
        }
        let mut rng = Pcg64::new(3);
        for _ in 0..500 {
            sk.update(rng.gen_range(32) as usize, rng.gen_range(32) as usize, 1.0);
        }
        let hh = sk.heavy_hitters(100.0);
        assert!(hh.len() >= 2, "found {hh:?}");
        assert_eq!((hh[0].0, hh[0].1), (1, 2));
        assert_eq!((hh[1].0, hh[1].1), (10, 20));
    }

    #[test]
    fn weighted_updates_and_deletions() {
        // turnstile model: negative weights cancel
        let mut sk = StreamSketch::new(16, 16, 8, 8, 3, 5);
        sk.update(4, 4, 10.0);
        sk.update(4, 4, -10.0);
        sk.update(2, 3, 7.5);
        assert!(sk.query(4, 4).abs() < 1e-9);
        assert!((sk.query(2, 3) - 7.5).abs() < 1e-9 + 7.5 * 0.5);
    }

    #[test]
    fn space_accounting() {
        let sk = StreamSketch::new(1000, 1000, 32, 32, 5, 0);
        assert_eq!(sk.space(), 5 * 32 * 32);
        // 1M key universe in 5120 counters
        assert!(sk.space() < 1000 * 1000 / 100);
    }
}
