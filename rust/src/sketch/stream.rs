//! Streaming frequency estimation over a 2-D key space — the intro's
//! motivating application (Demaine et al.: "determine essential features
//! of the traffic stream using limited space"), done with MTS instead of
//! a flat count sketch: keys are (src, dst) pairs and each axis is
//! hashed independently, so the sketch is an m1×m2 matrix that supports
//! row/column marginal queries ([`StreamSketch::row_marginal`] /
//! [`StreamSketch::col_marginal`]) as well as point queries.
//!
//! Median-of-d across independent hash families gives the usual
//! heavy-hitter guarantees; [`StreamSketch::heavy_hitters`] uses the
//! marginal estimates to prune the key grid before scanning, and
//! [`StreamSketch::top_k`] walks rows in marginal order with a bounded
//! min-heap so neither needs a full n1·n2 pass on skewed streams. The
//! marginal bound only holds for non-negative workloads, so the sketch
//! tracks a sticky [`StreamSketch::has_deletions`] flag (set by any
//! negative-weight update, propagated through merges and the codec) and
//! routes scans to the dense variants once it is set.
//!
//! The sketch is *linear* in the update stream, which is what the
//! [`crate::store`] subsystem builds on: [`StreamSketch::merge_scaled`]
//! adds (or subtracts — sliding-window expiry) another sketch of the
//! same hash family elementwise with zero accuracy loss.

use crate::hash::{HashSeeds, ModeHash};
use crate::sketch::kernel;
use crate::util::stats::median_inplace;
use std::cell::RefCell;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

thread_local! {
    /// Per-thread median scratch for [`StreamSketch::query`]: the serve
    /// path calls it once per key and `d` is tiny and constant, so one
    /// warm buffer removes a heap allocation per query.
    static QUERY_SCRATCH: RefCell<Vec<f64>> = RefCell::new(Vec::new());
}

/// Marginal-pruning slack for [`StreamSketch::heavy_hitters`]: a
/// row/column survives when its estimated marginal clears
/// `threshold * MARGINAL_PRUNE_SLACK`. Marginal estimates are unbiased
/// but noisy, so we keep a 2× safety margin instead of cutting at the
/// threshold itself.
const MARGINAL_PRUNE_SLACK: f64 = 0.5;

/// Early-exit slack for [`StreamSketch::top_k`]: stop scanning rows once
/// the current row's marginal estimate, inflated by this factor, cannot
/// reach the k-th best point estimate found so far.
const TOP_K_SLACK: f64 = 2.0;

/// d independent m1×m2 MTS counters over keys `[n1] × [n2]`.
#[derive(Clone, Debug)]
pub struct StreamSketch {
    pub n1: usize,
    pub n2: usize,
    pub m1: usize,
    pub m2: usize,
    pub d: usize,
    /// root seed the d hash families were derived from (part of the
    /// sketch identity: only same-seed sketches are mergeable)
    pub seed: u64,
    rows: Vec<ModeHash>,
    cols: Vec<ModeHash>,
    tables: Vec<Vec<f64>>,
    /// total updates processed
    pub updates: u64,
    /// true once any negative-weight update has been absorbed (directly
    /// or via merge). The marginal-pruned scans are only sound for
    /// non-negative streams — a deletion can cancel a row/column
    /// marginal while a heavy cell survives — so [`StreamSketch::top_k`]
    /// and [`StreamSketch::heavy_hitters`] fall back to the dense scans
    /// whenever this is set. Sticky (only [`StreamSketch::clear`]
    /// resets it): `false` proves the represented stream is
    /// non-negative, `true` is merely conservative.
    pub has_deletions: bool,
}

/// Min-heap entry for [`StreamSketch::top_k`] (ordered by estimate;
/// key as a deterministic tie-break so `Ord` is total).
struct TopEntry {
    est: f64,
    i: usize,
    j: usize,
}

impl PartialEq for TopEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for TopEntry {}

impl PartialOrd for TopEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for TopEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.est
            .total_cmp(&other.est)
            .then_with(|| self.i.cmp(&other.i))
            .then_with(|| self.j.cmp(&other.j))
    }
}

impl StreamSketch {
    pub fn new(n1: usize, n2: usize, m1: usize, m2: usize, d: usize, seed: u64) -> Self {
        assert!(d >= 1);
        let seeds = HashSeeds::new(seed);
        let rows = (0..d).map(|r| ModeHash::new(n1, m1, seeds.seed_for(r, 0))).collect();
        let cols = (0..d).map(|r| ModeHash::new(n2, m2, seeds.seed_for(r, 1))).collect();
        Self {
            n1,
            n2,
            m1,
            m2,
            d,
            seed,
            rows,
            cols,
            tables: vec![vec![0.0; m1 * m2]; d],
            updates: 0,
            has_deletions: false,
        }
    }

    /// Space used, in f64 counters.
    pub fn space(&self) -> usize {
        self.d * self.m1 * self.m2
    }

    /// Process one stream item: key (i, j) with weight `w` (e.g. bytes).
    pub fn update(&mut self, i: usize, j: usize, w: f64) {
        debug_assert!(i < self.n1 && j < self.n2);
        for r in 0..self.d {
            let b = self.rows[r].h(i) * self.m2 + self.cols[r].h(j);
            self.tables[r][b] += self.rows[r].s(i) * self.cols[r].s(j) * w;
        }
        self.updates += 1;
        if w < 0.0 {
            self.has_deletions = true;
        }
    }

    /// Apply one update to several **same-family** sketches at once,
    /// evaluating each repeat's bucket and signed contribution a single
    /// time (hashes and signs are pure functions of the shared family,
    /// so every target receives the identical `±w` at the identical
    /// bucket). The store's write path fans one update into a shard's
    /// epoch slot, running total, *and* scan-cache pending delta — this
    /// kernel makes that one hash walk instead of three. Bit-identical
    /// to calling [`StreamSketch::update`] on each target.
    pub fn update_fanout(targets: &mut [&mut StreamSketch], i: usize, j: usize, w: f64) {
        let Some((first, rest)) = targets.split_first_mut() else {
            return;
        };
        debug_assert!(i < first.n1 && j < first.n2);
        debug_assert!(rest.iter().all(|t| first.same_family(t)));
        for r in 0..first.d {
            // divless single-point walk: precomputed reducers + sign
            // bits (bit-identical to `h`/`s`, property-tested)
            let b = first.rows[r].h_fast(i) * first.m2 + first.cols[r].h_fast(j);
            let sb = first.rows[r].s_bit(i) ^ first.cols[r].s_bit(j);
            let v = kernel::sign_from_bit(sb) * w;
            first.tables[r][b] += v;
            for t in rest.iter_mut() {
                t.tables[r][b] += v;
            }
        }
        first.updates += 1;
        for t in rest.iter_mut() {
            t.updates += 1;
        }
        if w < 0.0 {
            first.has_deletions = true;
            for t in rest.iter_mut() {
                t.has_deletions = true;
            }
        }
    }

    /// Batched [`StreamSketch::update_fanout`]: one kernel hash phase
    /// per repeat and tile ([`crate::sketch::kernel`]), with the staged
    /// runs replayed into every target's table — the hash work is paid
    /// once no matter how many sketches the store fans into. Per target
    /// and table, items land in batch order — bit-identical to calling
    /// [`StreamSketch::update_batch`] on each target (and to
    /// [`StreamSketch::update_batch_fanout_scalar`]).
    pub fn update_batch_fanout(targets: &mut [&mut StreamSketch], items: &[(usize, usize, f64)]) {
        let Some(first) = targets.first() else {
            return;
        };
        let path = kernel::configured();
        if path == kernel::KernelPath::Scalar || first.m1 * first.m2 > u32::MAX as usize {
            crate::obs::global().kernel_scalar.inc();
            Self::update_batch_fanout_scalar(targets, items);
            return;
        }
        debug_assert!(targets.windows(2).all(|p| p[0].same_family(&p[1])));
        let d = targets[0].d;
        let m2 = targets[0].m2;
        kernel::with_scratch(|s| {
            for r in 0..d {
                let hash = kernel::Hash2d::new(&targets[0].rows[r], &targets[0].cols[r], m2);
                let table_len = targets[0].tables[r].len();
                for tile in items.chunks(kernel::TILE) {
                    kernel::hash_tile_2d(path, &hash, tile, &mut s.b, &mut s.v);
                    s.stage(table_len);
                    for t in targets.iter_mut() {
                        let (bs, vs) = s.runs();
                        kernel::apply_runs(&mut t.tables[r], bs, vs);
                    }
                }
            }
        });
        let n = items.len() as u64;
        let deletions = items.iter().any(|&(_, _, w)| w < 0.0);
        for t in targets.iter_mut() {
            t.updates += n;
            if deletions {
                t.has_deletions = true;
            }
        }
    }

    /// The pre-kernel scalar fan-out walk: hardware `%` and branchy
    /// signs, one fused pass per repeat. Kept public as the bit-identity
    /// oracle and bench baseline for the kernel path
    /// (`HOCS_KERNEL=scalar` routes
    /// [`StreamSketch::update_batch_fanout`] here).
    pub fn update_batch_fanout_scalar(
        targets: &mut [&mut StreamSketch],
        items: &[(usize, usize, f64)],
    ) {
        let Some((first, rest)) = targets.split_first_mut() else {
            return;
        };
        debug_assert!(rest.iter().all(|t| first.same_family(t)));
        for r in 0..first.d {
            for &(i, j, w) in items {
                debug_assert!(i < first.n1 && j < first.n2);
                let b = first.rows[r].h(i) * first.m2 + first.cols[r].h(j);
                let v = first.rows[r].s(i) * first.cols[r].s(j) * w;
                first.tables[r][b] += v;
                for t in rest.iter_mut() {
                    t.tables[r][b] += v;
                }
            }
        }
        let n = items.len() as u64;
        let deletions = items.iter().any(|&(_, _, w)| w < 0.0);
        first.updates += n;
        if deletions {
            first.has_deletions = true;
        }
        for t in rest.iter_mut() {
            t.updates += n;
            if deletions {
                t.has_deletions = true;
            }
        }
    }

    /// Fused multi-key update, routed through the two-phase kernel
    /// ([`crate::sketch::kernel`]): a lane-parallel hash phase turns
    /// each tile of items into flat `(bucket, signed_w)` runs, and a
    /// cache-blocked apply phase adds them into the repeat's table in
    /// batch order. **Bit-identical** to calling
    /// [`StreamSketch::update`] per item and to
    /// [`StreamSketch::update_batch_scalar`] on every dispatch path —
    /// see the kernel module's bit-identity argument.
    pub fn update_batch(&mut self, items: &[(usize, usize, f64)]) {
        let path = kernel::configured();
        if path == kernel::KernelPath::Scalar || self.m1 * self.m2 > u32::MAX as usize {
            crate::obs::global().kernel_scalar.inc();
            self.update_batch_scalar(items);
            return;
        }
        kernel::with_scratch(|s| {
            for r in 0..self.d {
                let hash = kernel::Hash2d::new(&self.rows[r], &self.cols[r], self.m2);
                let table = &mut self.tables[r];
                for tile in items.chunks(kernel::TILE) {
                    kernel::hash_tile_2d(path, &hash, tile, &mut s.b, &mut s.v);
                    s.stage(table.len());
                    let (bs, vs) = s.runs();
                    kernel::apply_runs(table, bs, vs);
                }
            }
        });
        self.updates += items.len() as u64;
        if items.iter().any(|&(_, _, w)| w < 0.0) {
            self.has_deletions = true;
        }
    }

    /// The pre-kernel fused walk: each repeat's hash pair and counter
    /// table walked once for the whole batch, hardware `%` and branchy
    /// signs per item. Kept public as the bit-identity oracle for the
    /// kernel paths and as the bench baseline (`HOCS_KERNEL=scalar`
    /// routes [`StreamSketch::update_batch`] here).
    pub fn update_batch_scalar(&mut self, items: &[(usize, usize, f64)]) {
        for r in 0..self.d {
            let row = &self.rows[r];
            let col = &self.cols[r];
            let m2 = self.m2;
            let table = &mut self.tables[r];
            for &(i, j, w) in items {
                debug_assert!(i < self.n1 && j < self.n2);
                table[row.h(i) * m2 + col.h(j)] += row.s(i) * col.s(j) * w;
            }
        }
        self.updates += items.len() as u64;
        if items.iter().any(|&(_, _, w)| w < 0.0) {
            self.has_deletions = true;
        }
    }

    /// Point query: median-of-d estimate of the total weight of (i, j).
    /// Runs through per-thread scratch, so the steady-state serve path
    /// allocates nothing per query.
    pub fn query(&self, i: usize, j: usize) -> f64 {
        QUERY_SCRATCH.with(|cell| {
            let mut est = cell.borrow_mut();
            est.clear();
            est.resize(self.d, 0.0);
            self.query_scratch(i, j, &mut est)
        })
    }

    /// [`StreamSketch::query`] into caller-owned scratch (the scan paths
    /// call this per cell; one allocation per scan instead of per key).
    fn query_scratch(&self, i: usize, j: usize, est: &mut [f64]) -> f64 {
        debug_assert_eq!(est.len(), self.d);
        for (r, e) in est.iter_mut().enumerate() {
            let b = self.rows[r].h(i) * self.m2 + self.cols[r].h(j);
            *e = self.rows[r].s(i) * self.cols[r].s(j) * self.tables[r][b];
        }
        median_inplace(est)
    }

    /// Add this sketch's raw bucket counters for key (i, j) into
    /// `acc[r]` — no signs yet. The store's fan-out point query sums raw
    /// counters across sketches of disjoint substreams, then applies the
    /// signs once in [`StreamSketch::finalize_estimates`]: by linearity
    /// the summed counter equals the merged sketch's counter, and
    /// because the sign multiplies the *sum* (not each addend) the
    /// result is bit-identical to querying the merged sketch — signed
    /// zeros included, which summing pre-signed estimates would get
    /// wrong on zero-sum buckets split across shards.
    pub fn accumulate_raw(&self, i: usize, j: usize, acc: &mut [f64]) {
        assert_eq!(acc.len(), self.d, "accumulator length {} != d {}", acc.len(), self.d);
        for (r, a) in acc.iter_mut().enumerate() {
            *a += self.tables[r][self.rows[r].h(i) * self.m2 + self.cols[r].h(j)];
        }
    }

    /// Turn counters summed by [`StreamSketch::accumulate_raw`] into the
    /// median-of-d point estimate for key (i, j). Any same-family sketch
    /// (e.g. an empty probe) produces identical signs.
    pub fn finalize_estimates(&self, i: usize, j: usize, acc: &mut [f64]) -> f64 {
        assert_eq!(acc.len(), self.d, "accumulator length {} != d {}", acc.len(), self.d);
        for (r, a) in acc.iter_mut().enumerate() {
            *a *= self.rows[r].s(i) * self.cols[r].s(j);
        }
        median_inplace(acc)
    }

    // ---------- marginals ----------

    /// Estimated total weight of row key `i` (Σ_j count(i, j)): per
    /// repeat, sum the hashed row with column signs, then median-of-d.
    /// Unbiased; O(n2·d). For all rows at once use
    /// [`StreamSketch::row_marginals`].
    pub fn row_marginal(&self, i: usize) -> f64 {
        assert!(i < self.n1, "row {i} out of range (n1 = {})", self.n1);
        let mut est: Vec<f64> = (0..self.d)
            .map(|r| {
                let base = self.rows[r].h(i) * self.m2;
                let t = &self.tables[r];
                let col = &self.cols[r];
                let mut acc = 0.0;
                for j in 0..self.n2 {
                    acc += col.s(j) * t[base + col.h(j)];
                }
                self.rows[r].s(i) * acc
            })
            .collect();
        median_inplace(&mut est)
    }

    /// Estimated total weight of column key `j` (Σ_i count(i, j)).
    /// Unbiased; O(n1·d). For all columns at once use
    /// [`StreamSketch::col_marginals`].
    pub fn col_marginal(&self, j: usize) -> f64 {
        assert!(j < self.n2, "col {j} out of range (n2 = {})", self.n2);
        let mut est: Vec<f64> = (0..self.d)
            .map(|r| {
                let t = &self.tables[r];
                let row = &self.rows[r];
                let hj = self.cols[r].h(j);
                let mut acc = 0.0;
                for i in 0..self.n1 {
                    acc += row.s(i) * t[row.h(i) * self.m2 + hj];
                }
                self.cols[r].s(j) * acc
            })
            .collect();
        median_inplace(&mut est)
    }

    /// All row marginals. Per repeat, the column-signed sum of every
    /// *bucket* row is materialized once (O(m1·n2)), then each of the n1
    /// row keys is an O(1) lookup — O(d·(m1·n2 + n1)) total instead of
    /// the O(d·n1·n2) of n1 separate [`StreamSketch::row_marginal`]
    /// calls, with bit-identical results (same summation order).
    pub fn row_marginals(&self) -> Vec<f64> {
        let mut per_table: Vec<Vec<f64>> = Vec::with_capacity(self.d);
        for r in 0..self.d {
            let t = &self.tables[r];
            let col = &self.cols[r];
            let mut agg = vec![0.0; self.m1];
            for j in 0..self.n2 {
                let (hj, sj) = (col.h(j), col.s(j));
                for (b1, a) in agg.iter_mut().enumerate() {
                    *a += sj * t[b1 * self.m2 + hj];
                }
            }
            per_table.push(agg);
        }
        let mut est = vec![0.0; self.d];
        (0..self.n1)
            .map(|i| {
                for (r, e) in est.iter_mut().enumerate() {
                    *e = self.rows[r].s(i) * per_table[r][self.rows[r].h(i)];
                }
                median_inplace(&mut est)
            })
            .collect()
    }

    /// All column marginals (see [`StreamSketch::row_marginals`]).
    pub fn col_marginals(&self) -> Vec<f64> {
        let mut per_table: Vec<Vec<f64>> = Vec::with_capacity(self.d);
        for r in 0..self.d {
            let t = &self.tables[r];
            let row = &self.rows[r];
            let mut agg = vec![0.0; self.m2];
            for i in 0..self.n1 {
                let (hi, si) = (row.h(i), row.s(i));
                for (b2, a) in agg.iter_mut().enumerate() {
                    *a += si * t[hi * self.m2 + b2];
                }
            }
            per_table.push(agg);
        }
        let mut est = vec![0.0; self.d];
        (0..self.n2)
            .map(|j| {
                for (r, e) in est.iter_mut().enumerate() {
                    *e = self.cols[r].s(j) * per_table[r][self.cols[r].h(j)];
                }
                median_inplace(&mut est)
            })
            .collect()
    }

    // ---------- scans ----------

    /// All keys whose estimated weight is ≥ `threshold`, sorted
    /// descending. For non-negative streams a cell's count is bounded by
    /// its row and column marginals, so only rows/columns whose estimated
    /// marginal clears `threshold/2` (noise slack) are scanned — on
    /// skewed traffic that is a few candidate rows instead of the whole
    /// n1×n2 grid. Turnstile streams (any negative-weight update seen:
    /// [`StreamSketch::has_deletions`]) are routed to the full
    /// [`StreamSketch::heavy_hitters_dense`] scan automatically, because
    /// a deletion-cancelled marginal can hide a surviving heavy cell.
    pub fn heavy_hitters(&self, threshold: f64) -> Vec<(usize, usize, f64)> {
        if self.has_deletions {
            return self.heavy_hitters_dense(threshold);
        }
        let cut = threshold * MARGINAL_PRUNE_SLACK;
        let rows: Vec<usize> = self
            .row_marginals()
            .iter()
            .enumerate()
            .filter_map(|(i, &m)| (m >= cut).then_some(i))
            .collect();
        if rows.is_empty() {
            return Vec::new();
        }
        let cols: Vec<usize> = self
            .col_marginals()
            .iter()
            .enumerate()
            .filter_map(|(j, &m)| (m >= cut).then_some(j))
            .collect();
        let mut out = Vec::new();
        let mut est = vec![0.0; self.d];
        for &i in &rows {
            for &j in &cols {
                let w = self.query_scratch(i, j, &mut est);
                if w >= threshold {
                    out.push((i, j, w));
                }
            }
        }
        out.sort_by(|a, b| b.2.total_cmp(&a.2));
        out
    }

    /// Unpruned full-grid scan (the pre-marginal behaviour): correct for
    /// arbitrary turnstile streams, O(n1·n2·d).
    pub fn heavy_hitters_dense(&self, threshold: f64) -> Vec<(usize, usize, f64)> {
        let mut out = Vec::new();
        let mut est = vec![0.0; self.d];
        for i in 0..self.n1 {
            for j in 0..self.n2 {
                let w = self.query_scratch(i, j, &mut est);
                if w >= threshold {
                    out.push((i, j, w));
                }
            }
        }
        out.sort_by(|a, b| b.2.total_cmp(&a.2));
        out
    }

    /// The k keys with the largest estimated weight, sorted descending.
    ///
    /// Rows are visited in decreasing estimated-marginal order while a
    /// size-k min-heap tracks the best cells; once the heap is full and a
    /// row's marginal (×[`TOP_K_SLACK`] for estimator noise) cannot beat
    /// the k-th best estimate, no later row can either (for non-negative
    /// streams a cell never exceeds its row marginal) and the scan stops.
    /// On skewed streams this touches a handful of rows, which is what
    /// makes the store's TOPK RPC affordable per call.
    ///
    /// The marginal bound only holds for non-negative streams; once any
    /// deletion has been absorbed ([`StreamSketch::has_deletions`]) the
    /// scan falls back to [`StreamSketch::top_k_dense`].
    pub fn top_k(&self, k: usize) -> Vec<(usize, usize, f64)> {
        if k == 0 {
            return Vec::new();
        }
        if self.has_deletions {
            return self.top_k_dense(k);
        }
        let rm = self.row_marginals();
        let mut order: Vec<usize> = (0..self.n1).collect();
        order.sort_by(|&a, &b| rm[b].total_cmp(&rm[a]));
        self.top_k_scan(k, &order, Some(&rm))
    }

    /// Unpruned top-k: the full n1·n2 grid through a size-k min-heap,
    /// no marginal ordering or early exit. Correct for arbitrary
    /// turnstile streams; same ranking semantics as
    /// [`StreamSketch::top_k`] (estimate-descending, deterministic
    /// key tie-break) — both go through the one scan loop in
    /// [`StreamSketch::top_k_scan`].
    pub fn top_k_dense(&self, k: usize) -> Vec<(usize, usize, f64)> {
        if k == 0 {
            return Vec::new();
        }
        let order: Vec<usize> = (0..self.n1).collect();
        self.top_k_scan(k, &order, None)
    }

    /// The size-k min-heap scan shared by [`StreamSketch::top_k`] and
    /// [`StreamSketch::top_k_dense`]: visit `rows` in the given order,
    /// rank every cell. With `bound` (a per-row upper bound on any cell
    /// estimate, rows sorted bound-descending), the scan stops at the
    /// first row whose slack-inflated bound cannot beat the k-th best.
    fn top_k_scan(
        &self,
        k: usize,
        rows: &[usize],
        bound: Option<&[f64]>,
    ) -> Vec<(usize, usize, f64)> {
        let mut heap: BinaryHeap<std::cmp::Reverse<TopEntry>> =
            BinaryHeap::with_capacity(k + 1);
        let mut est = vec![0.0; self.d];
        for &i in rows {
            if let Some(rm) = bound {
                if heap.len() == k {
                    let kth = heap.peek().expect("heap non-empty").0.est;
                    if rm[i] * TOP_K_SLACK < kth {
                        break;
                    }
                }
            }
            for j in 0..self.n2 {
                let e = self.query_scratch(i, j, &mut est);
                if heap.len() < k {
                    heap.push(std::cmp::Reverse(TopEntry { est: e, i, j }));
                } else if e > heap.peek().expect("heap non-empty").0.est {
                    heap.pop();
                    heap.push(std::cmp::Reverse(TopEntry { est: e, i, j }));
                }
            }
        }
        let mut out: Vec<(usize, usize, f64)> =
            heap.into_iter().map(|std::cmp::Reverse(e)| (e.i, e.j, e.est)).collect();
        out.sort_by(|a, b| b.2.total_cmp(&a.2));
        out
    }

    // ---------- linearity (merge / scale / clear) ----------

    /// True when `other` was built over the same key universe, sketch
    /// geometry, and hash-family seed — the precondition for elementwise
    /// merging to be meaningful.
    pub fn same_family(&self, other: &Self) -> bool {
        self.n1 == other.n1
            && self.n2 == other.n2
            && self.m1 == other.m1
            && self.m2 == other.m2
            && self.d == other.d
            && self.seed == other.seed
    }

    /// `self += a · other`, elementwise over all d tables. With `a = 1`
    /// this is the sketch of the concatenated streams (count sketches
    /// are linear maps — zero accuracy loss); with `a = -1` it deletes a
    /// **previously-added substream**, which is how the store expires
    /// window epochs. That sub-stream contract is why a negative `a`
    /// does not set [`StreamSketch::has_deletions`] by itself: removing
    /// mass that was added leaves the represented stream non-negative if
    /// it was before. `other`'s own deletion flag always propagates.
    pub fn merge_scaled(&mut self, other: &Self, a: f64) {
        assert!(self.same_family(other), "merge of incompatible stream sketches");
        for (t, o) in self.tables.iter_mut().zip(other.tables.iter()) {
            for (x, y) in t.iter_mut().zip(o.iter()) {
                *x += a * y;
            }
        }
        if a >= 0.0 {
            self.updates += other.updates;
        } else {
            self.updates = self.updates.saturating_sub(other.updates);
        }
        self.has_deletions |= other.has_deletions;
    }

    /// `self *= a` (decay weighting). `updates` is left untouched: it
    /// counts stream items, not mass.
    pub fn scale_tables(&mut self, a: f64) {
        for t in &mut self.tables {
            for x in t.iter_mut() {
                *x *= a;
            }
        }
    }

    /// Zero all counters (reused window slots).
    pub fn clear(&mut self) {
        for t in &mut self.tables {
            t.fill(0.0);
        }
        self.updates = 0;
        self.has_deletions = false;
    }

    /// Raw counter table of repeat `r` (serialization / diagnostics).
    pub fn table(&self, r: usize) -> &[f64] {
        &self.tables[r]
    }

    /// Mutable raw counter table of repeat `r` (deserialization only —
    /// writing anything but a valid same-family table corrupts queries).
    pub fn table_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.tables[r]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn point_queries_track_true_counts() {
        let mut sk = StreamSketch::new(64, 64, 16, 16, 5, 1);
        let mut truth = std::collections::HashMap::new();
        let mut rng = Pcg64::new(2);
        // zipf-ish: a few heavy keys + light noise
        for _ in 0..5000 {
            let (i, j) = if rng.uniform() < 0.5 {
                (3usize, 7usize)
            } else if rng.uniform() < 0.5 {
                (40, 9)
            } else {
                (rng.gen_range(64) as usize, rng.gen_range(64) as usize)
            };
            sk.update(i, j, 1.0);
            *truth.entry((i, j)).or_insert(0.0f64) += 1.0;
        }
        let t1 = truth[&(3, 7)];
        let e1 = sk.query(3, 7);
        assert!((e1 - t1).abs() < 0.15 * t1, "heavy key: {e1} vs {t1}");
        let t2 = truth[&(40, 9)];
        let e2 = sk.query(40, 9);
        assert!((e2 - t2).abs() < 0.15 * t2, "heavy key: {e2} vs {t2}");
    }

    #[test]
    fn heavy_hitters_found_in_order() {
        let mut sk = StreamSketch::new(32, 32, 12, 12, 5, 7);
        for _ in 0..300 {
            sk.update(1, 2, 1.0);
        }
        for _ in 0..150 {
            sk.update(10, 20, 1.0);
        }
        let mut rng = Pcg64::new(3);
        for _ in 0..500 {
            sk.update(rng.gen_range(32) as usize, rng.gen_range(32) as usize, 1.0);
        }
        let hh = sk.heavy_hitters(100.0);
        assert!(hh.len() >= 2, "found {hh:?}");
        assert_eq!((hh[0].0, hh[0].1), (1, 2));
        assert_eq!((hh[1].0, hh[1].1), (10, 20));
    }

    #[test]
    fn pruned_heavy_hitters_match_dense_scan() {
        // non-negative stream: the marginal pruning must not lose any hit
        let mut sk = StreamSketch::new(48, 40, 14, 12, 5, 11);
        let mut rng = Pcg64::new(4);
        for _ in 0..400 {
            sk.update(5, 6, 1.0);
        }
        for _ in 0..220 {
            sk.update(33, 1, 1.0);
        }
        for _ in 0..800 {
            sk.update(rng.gen_range(48) as usize, rng.gen_range(40) as usize, 1.0);
        }
        for threshold in [80.0, 150.0, 300.0] {
            let pruned = sk.heavy_hitters(threshold);
            let dense = sk.heavy_hitters_dense(threshold);
            assert_eq!(pruned, dense, "threshold {threshold}");
        }
    }

    #[test]
    fn top_k_matches_full_scan_ranking() {
        let mut sk = StreamSketch::new(32, 32, 16, 16, 5, 9);
        let mut rng = Pcg64::new(5);
        for _ in 0..500 {
            sk.update(2, 3, 1.0);
        }
        for _ in 0..250 {
            sk.update(17, 8, 1.0);
        }
        for _ in 0..120 {
            sk.update(30, 30, 1.0);
        }
        for _ in 0..400 {
            sk.update(rng.gen_range(32) as usize, rng.gen_range(32) as usize, 1.0);
        }
        let top = sk.top_k(3);
        assert_eq!(top.len(), 3);
        assert_eq!((top[0].0, top[0].1), (2, 3));
        assert_eq!((top[1].0, top[1].1), (17, 8));
        assert_eq!((top[2].0, top[2].1), (30, 30));
        // against the oracle: dense scan sorted by estimate
        let mut dense: Vec<(usize, usize, f64)> = Vec::new();
        for i in 0..32 {
            for j in 0..32 {
                dense.push((i, j, sk.query(i, j)));
            }
        }
        dense.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        for (got, want) in top.iter().zip(dense.iter()) {
            assert_eq!(got.2.to_bits(), want.2.to_bits());
        }
    }

    #[test]
    fn top_k_edge_cases() {
        let mut sk = StreamSketch::new(8, 8, 4, 4, 3, 1);
        assert!(sk.top_k(0).is_empty());
        sk.update(1, 1, 5.0);
        // k larger than the universe: returns every cell, ranked
        let all = sk.top_k(100);
        assert_eq!(all.len(), 64);
        // hash collisions can tie other cells at ±5, so assert the true
        // key is at the top estimate rather than literally first
        assert!((all[0].2 - 5.0).abs() < 1e-12, "top estimate {}", all[0].2);
        assert!(
            all.iter().any(|&(i, j, e)| i == 1 && j == 1 && (e - 5.0).abs() < 1e-12),
            "true key missing from ranking"
        );
    }

    #[test]
    fn marginals_track_true_sums() {
        // Marginal estimators carry own-mass collision noise of order
        // mass/sqrt(m), so tolerances are ~4 median-of-d sigmas wide.
        let mut sk = StreamSketch::new(40, 36, 16, 16, 7, 13);
        let mut rng = Pcg64::new(6);
        let mut row_truth = vec![0.0f64; 40];
        let mut col_truth = vec![0.0f64; 36];
        let mut hit = |sk: &mut StreamSketch, i: usize, j: usize| {
            sk.update(i, j, 1.0);
            row_truth[i] += 1.0;
            col_truth[j] += 1.0;
        };
        for _ in 0..600 {
            let j = rng.gen_range(36) as usize;
            hit(&mut sk, 7, j);
        }
        for _ in 0..600 {
            let i = rng.gen_range(40) as usize;
            hit(&mut sk, i, 9);
        }
        for _ in 0..500 {
            let (i, j) = (rng.gen_range(40) as usize, rng.gen_range(36) as usize);
            hit(&mut sk, i, j);
        }
        let row_est = sk.row_marginal(7);
        assert!(
            (row_est - row_truth[7]).abs() < 0.4 * row_truth[7],
            "row marginal {row_est} vs {}",
            row_truth[7]
        );
        let col_est = sk.col_marginal(9);
        assert!(
            (col_est - col_truth[9]).abs() < 0.4 * col_truth[9],
            "col marginal {col_est} vs {}",
            col_truth[9]
        );
        // batched paths are bit-identical to the single-key paths
        let all_rows = sk.row_marginals();
        for (i, m) in all_rows.iter().enumerate() {
            assert_eq!(m.to_bits(), sk.row_marginal(i).to_bits(), "row {i}");
        }
        let all_cols = sk.col_marginals();
        for (j, m) in all_cols.iter().enumerate() {
            assert_eq!(m.to_bits(), sk.col_marginal(j).to_bits(), "col {j}");
        }
    }

    #[test]
    fn update_batch_bit_identical_to_single_updates() {
        let mut batched = StreamSketch::new(48, 40, 12, 10, 5, 19);
        let mut single = StreamSketch::new(48, 40, 12, 10, 5, 19);
        let mut rng = Pcg64::new(12);
        let items: Vec<(usize, usize, f64)> = (0..500)
            .map(|_| {
                (rng.gen_range(48) as usize, rng.gen_range(40) as usize, rng.normal())
            })
            .collect();
        // split the batch so the fused path also composes across calls
        batched.update_batch(&items[..123]);
        batched.update_batch(&items[123..]);
        batched.update_batch(&[]);
        for &(i, j, w) in &items {
            single.update(i, j, w);
        }
        assert_eq!(batched.updates, single.updates);
        assert_eq!(batched.has_deletions, single.has_deletions);
        for r in 0..5 {
            assert_eq!(batched.table(r), single.table(r), "table {r}");
        }
    }

    #[test]
    fn fanout_updates_bit_identical_to_per_sketch_updates() {
        // three same-family sketches driven through the fused fan-out
        // kernels must match three driven individually, bit for bit —
        // including the updates counter and the turnstile flag
        let mk = || StreamSketch::new(48, 40, 12, 10, 5, 23);
        let (mut fa, mut fb, mut fc) = (mk(), mk(), mk());
        let (mut sa, mut sb, mut sc) = (mk(), mk(), mk());
        let mut rng = Pcg64::new(77);
        let items: Vec<(usize, usize, f64)> = (0..300)
            .map(|_| {
                (rng.gen_range(48) as usize, rng.gen_range(40) as usize, rng.normal())
            })
            .collect();
        for &(i, j, w) in &items[..150] {
            StreamSketch::update_fanout(&mut [&mut fa, &mut fb, &mut fc], i, j, w);
            sa.update(i, j, w);
            sb.update(i, j, w);
            sc.update(i, j, w);
        }
        StreamSketch::update_batch_fanout(&mut [&mut fa, &mut fb, &mut fc], &items[150..]);
        StreamSketch::update_batch_fanout(&mut [&mut fa, &mut fb, &mut fc], &[]);
        sa.update_batch(&items[150..]);
        sb.update_batch(&items[150..]);
        sc.update_batch(&items[150..]);
        for (fanned, single) in [(&fa, &sa), (&fb, &sb), (&fc, &sc)] {
            assert_eq!(fanned.updates, single.updates);
            assert_eq!(fanned.has_deletions, single.has_deletions);
            for r in 0..5 {
                assert_eq!(fanned.table(r), single.table(r), "table {r}");
            }
        }
        // degenerate target lists are no-ops
        StreamSketch::update_fanout(&mut [], 1, 1, 1.0);
        StreamSketch::update_batch_fanout(&mut [], &items);
    }

    #[test]
    fn deletion_flag_tracks_stream_and_merges() {
        let mut sk = StreamSketch::new(8, 8, 4, 4, 3, 2);
        assert!(!sk.has_deletions);
        sk.update(1, 1, 2.0);
        assert!(!sk.has_deletions);
        sk.update(1, 1, -1.0);
        assert!(sk.has_deletions);
        // the flag propagates through merges (either direction of mass)
        let mut clean = StreamSketch::new(8, 8, 4, 4, 3, 2);
        clean.merge_scaled(&sk, 1.0);
        assert!(clean.has_deletions);
        // subtracting a clean sub-stream does not set the flag
        let mut a = StreamSketch::new(8, 8, 4, 4, 3, 2);
        let mut b = StreamSketch::new(8, 8, 4, 4, 3, 2);
        a.update(2, 2, 3.0);
        b.update(2, 2, 3.0);
        a.merge_scaled(&b, -1.0);
        assert!(!a.has_deletions);
        // clear() resets it (window slots are reused)
        sk.clear();
        assert!(!sk.has_deletions);
        // and the batch path sets it too
        sk.update_batch(&[(1, 1, 1.0), (2, 2, -2.0)]);
        assert!(sk.has_deletions);
    }

    #[test]
    fn deletion_cancelled_marginal_does_not_hide_heavy_cell() {
        // Adversarial turnstile stream: (5, 6) carries +300 while a
        // deletion at (5, 7) drives the *row-5 marginal* negative, so
        // the marginal-pruned scans would drop row 5 and hide the
        // surviving heavy cell. Seeds are searched so the test pins a
        // hash family where that hiding provably happens (negative
        // marginal, intact point estimate) — the exact regression.
        let threshold = 200.0;
        let cut = threshold * MARGINAL_PRUNE_SLACK;
        let mut chosen = None;
        for seed in 0..64 {
            let mut sk = StreamSketch::new(16, 16, 16, 16, 5, seed);
            sk.update(5, 6, 300.0);
            sk.update(5, 7, -300.0);
            let rm = sk.row_marginals()[5];
            if sk.query(5, 6) >= threshold && rm < 0.0 && rm < cut {
                chosen = Some(sk);
                break;
            }
        }
        let sk = chosen.expect("no seed produced a cancelled marginal with a live heavy cell");
        assert!(sk.has_deletions);
        let hh = sk.heavy_hitters(threshold);
        assert!(
            hh.iter().any(|&(i, j, _)| (i, j) == (5, 6)),
            "pruned scan hid the heavy cell: {hh:?}"
        );
        assert_eq!(hh, sk.heavy_hitters_dense(threshold), "routing must hit the dense scan");
        let top = sk.top_k(3);
        assert!(
            top.iter().any(|&(i, j, _)| (i, j) == (5, 6)),
            "top-k hid the heavy cell: {top:?}"
        );
        assert_eq!(top, sk.top_k_dense(3));
    }

    #[test]
    fn weighted_updates_and_deletions() {
        // turnstile model: negative weights cancel
        let mut sk = StreamSketch::new(16, 16, 8, 8, 3, 5);
        sk.update(4, 4, 10.0);
        sk.update(4, 4, -10.0);
        sk.update(2, 3, 7.5);
        assert!(sk.query(4, 4).abs() < 1e-9);
        assert!((sk.query(2, 3) - 7.5).abs() < 1e-9 + 7.5 * 0.5);
    }

    #[test]
    fn space_accounting() {
        let sk = StreamSketch::new(1000, 1000, 32, 32, 5, 0);
        assert_eq!(sk.space(), 5 * 32 * 32);
        // 1M key universe in 5120 counters
        assert!(sk.space() < 1000 * 1000 / 100);
    }

    #[test]
    fn merge_equals_concatenated_stream() {
        let mut a = StreamSketch::new(32, 32, 8, 8, 5, 21);
        let mut b = StreamSketch::new(32, 32, 8, 8, 5, 21);
        let mut whole = StreamSketch::new(32, 32, 8, 8, 5, 21);
        let mut rng = Pcg64::new(8);
        for step in 0..500 {
            let (i, j) = (rng.gen_range(32) as usize, rng.gen_range(32) as usize);
            let w = (1 + rng.gen_range(9)) as f64; // integer weights: exact sums
            if step % 2 == 0 {
                a.update(i, j, w);
            } else {
                b.update(i, j, w);
            }
            whole.update(i, j, w);
        }
        a.merge_scaled(&b, 1.0);
        assert_eq!(a.updates, whole.updates);
        for r in 0..5 {
            assert_eq!(a.table(r), whole.table(r), "table {r}");
        }
        // and subtracting b back leaves exactly the a-substream
        let mut rng2 = Pcg64::new(8);
        let mut only_a = StreamSketch::new(32, 32, 8, 8, 5, 21);
        for step in 0..500 {
            let (i, j) = (rng2.gen_range(32) as usize, rng2.gen_range(32) as usize);
            let w = (1 + rng2.gen_range(9)) as f64;
            if step % 2 == 0 {
                only_a.update(i, j, w);
            }
        }
        a.merge_scaled(&b, -1.0);
        for r in 0..5 {
            assert_eq!(a.table(r), only_a.table(r), "table {r} after subtract");
        }
    }

    #[test]
    #[should_panic(expected = "incompatible")]
    fn merge_rejects_different_seed() {
        let mut a = StreamSketch::new(8, 8, 4, 4, 3, 1);
        let b = StreamSketch::new(8, 8, 4, 4, 3, 2);
        a.merge_scaled(&b, 1.0);
    }

    #[test]
    fn scale_and_clear() {
        let mut sk = StreamSketch::new(8, 8, 4, 4, 3, 3);
        sk.update(1, 2, 4.0);
        sk.scale_tables(0.5);
        assert!((sk.query(1, 2) - 2.0).abs() < 1e-12);
        sk.clear();
        assert_eq!(sk.query(1, 2), 0.0);
        assert_eq!(sk.updates, 0);
    }

    #[test]
    fn raw_accumulation_plus_finalize_matches_query() {
        let mut sk = StreamSketch::new(16, 16, 6, 6, 5, 17);
        let mut rng = Pcg64::new(9);
        for _ in 0..300 {
            sk.update(rng.gen_range(16) as usize, rng.gen_range(16) as usize, 1.0);
        }
        // a fresh same-family probe supplies identical signs
        let probe = StreamSketch::new(16, 16, 6, 6, 5, 17);
        for i in 0..16 {
            for j in 0..16 {
                let mut acc = vec![0.0; 5];
                sk.accumulate_raw(i, j, &mut acc);
                let est = probe.finalize_estimates(i, j, &mut acc);
                assert_eq!(est.to_bits(), sk.query(i, j).to_bits(), "key ({i}, {j})");
            }
        }
    }

    fn table_bits(sk: &StreamSketch, r: usize) -> Vec<u64> {
        sk.table(r).iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn kernel_batch_bit_identical_across_remainders_and_tiles() {
        // batch sizes exercising the lane remainder (0, 1, LANES±1) and
        // the tile boundary (4096 ± 1), over pow2 geometry (AVX2
        // eligible) and non-pow2 geometry (portable lanes + magic
        // reducers); weights include deletions
        for (m1, m2) in [(16usize, 16usize), (12, 10)] {
            for n in [0usize, 1, 7, 8, 9, 4095, 4096, 4097] {
                let mut kern = StreamSketch::new(64, 64, m1, m2, 3, 29);
                let mut scal = StreamSketch::new(64, 64, m1, m2, 3, 29);
                let mut rng = Pcg64::new(n as u64 + 1);
                let items: Vec<(usize, usize, f64)> = (0..n)
                    .map(|_| {
                        (rng.gen_range(64) as usize, rng.gen_range(64) as usize, rng.normal())
                    })
                    .collect();
                kern.update_batch(&items);
                scal.update_batch_scalar(&items);
                assert_eq!(kern.updates, scal.updates);
                assert_eq!(kern.has_deletions, scal.has_deletions);
                for r in 0..3 {
                    assert_eq!(
                        table_bits(&kern, r),
                        table_bits(&scal, r),
                        "m=({m1},{m2}) n={n} table {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn kernel_fanout_bit_identical_for_widths_1_to_4() {
        for width in 1usize..=4 {
            let mk = || StreamSketch::new(48, 40, 16, 16, 3, 31);
            let mut fan: Vec<StreamSketch> = (0..width).map(|_| mk()).collect();
            let mut solo: Vec<StreamSketch> = (0..width).map(|_| mk()).collect();
            let mut rng = Pcg64::new(width as u64);
            let items: Vec<(usize, usize, f64)> = (0..700)
                .map(|_| {
                    (rng.gen_range(48) as usize, rng.gen_range(40) as usize, rng.normal())
                })
                .collect();
            {
                let mut refs: Vec<&mut StreamSketch> = fan.iter_mut().collect();
                StreamSketch::update_batch_fanout(&mut refs, &items);
            }
            for s in solo.iter_mut() {
                s.update_batch_scalar(&items);
            }
            for (f, s) in fan.iter().zip(solo.iter()) {
                assert_eq!(f.updates, s.updates);
                assert_eq!(f.has_deletions, s.has_deletions);
                for r in 0..3 {
                    assert_eq!(table_bits(f, r), table_bits(s, r), "width {width} table {r}");
                }
            }
        }
    }

    #[test]
    fn kernel_blocked_apply_engages_on_large_tables() {
        // 512·256 = 131072 counters, past the kernel's direct-apply cap,
        // with enough items that the staged (block-partitioned) apply
        // path runs — results must stay bit-identical to batch order
        let mut kern = StreamSketch::new(4096, 4096, 512, 256, 2, 37);
        let mut scal = StreamSketch::new(4096, 4096, 512, 256, 2, 37);
        let mut rng = Pcg64::new(41);
        let items: Vec<(usize, usize, f64)> = (0..3000)
            .map(|_| {
                (rng.gen_range(4096) as usize, rng.gen_range(4096) as usize, rng.normal())
            })
            .collect();
        kern.update_batch(&items);
        scal.update_batch_scalar(&items);
        for r in 0..2 {
            assert_eq!(table_bits(&kern, r), table_bits(&scal, r), "table {r}");
        }
    }
}
