//! Covariance matrix estimation (§4.2, Figure 9).
//!
//! Given `A ∈ ℝ^{n×r}`, two routes to `AAᵀ`:
//!
//! - [`PaghCovariance`] — the baseline: Pagh (2012) compressed matrix
//!   multiplication. `CS(AAᵀ)` under the pair hash
//!   `h(i,j) = h₁(i)+h₂(j) mod c`, computed as
//!   `IFFT(Σ_k FFT(CS₁(A[:,k])) ∘ FFT(CS₂(Aᵀ[k,:])))`.
//! - [`MtsCovariance`] — the paper's route: sketch `A ⊗ Aᵀ` with
//!   [`super::kron::MtsKron`] and use
//!   `(AAᵀ)_{ij} = Σ_k (A ⊗ Aᵀ)[r·i + k, n·k + j]` (0-based version of
//!   the paper's identity) to read the covariance entries out of the
//!   Kronecker sketch.
//!
//! Both support median-of-d estimation (the paper uses 300 repeats).

use super::cs::CsSketcher;
use super::kron::MtsKron;
use crate::fft::{self, Complex};
use crate::hash::HashSeeds;
use crate::tensor::Tensor;
use crate::util::stats::median_inplace;

/// Pagh compressed-matrix-multiplication sketch of `A·Aᵀ`.
#[derive(Clone, Debug)]
pub struct PaghCovariance {
    pub n: usize,
    pub r: usize,
    pub c: usize,
    cs_row: CsSketcher,
    cs_col: CsSketcher,
}

impl PaghCovariance {
    pub fn new(n: usize, r: usize, c: usize, seed: u64) -> Self {
        Self::with_repeat(n, r, c, seed, 0)
    }

    pub fn with_repeat(n: usize, r: usize, c: usize, seed: u64, repeat: usize) -> Self {
        let seeds = HashSeeds::new(seed);
        Self {
            n,
            r,
            c,
            cs_row: CsSketcher::new(n, c, seeds.seed_for(repeat, 0)),
            cs_col: CsSketcher::new(n, c, seeds.seed_for(repeat, 1)),
        }
    }

    /// Compression ratio n²/c.
    pub fn compression_ratio(&self) -> f64 {
        (self.n * self.n) as f64 / self.c as f64
    }

    /// `CS(AAᵀ) = IFFT(Σ_k FFT(CS₁(A[:,k])) ∘ FFT(CS₂(A[:,k])))`,
    /// accumulated on half spectra (real inputs).
    pub fn sketch(&self, a: &Tensor) -> Vec<f64> {
        assert_eq!(a.dims(), &[self.n, self.r]);
        let hc = self.c / 2 + 1;
        let mut acc = vec![Complex::ZERO; hc];
        for k in 0..self.r {
            let col = a.col(k);
            let f1 = fft::rfft(&self.cs_row.sketch(&col));
            let f2 = fft::rfft(&self.cs_col.sketch(&col));
            for ((x, y), z) in f1.iter().zip(f2.iter()).zip(acc.iter_mut()) {
                *z += *x * *y;
            }
        }
        fft::irfft(&acc, self.c)
    }

    /// Estimate `(AAᵀ)[i, j]`.
    #[inline]
    pub fn estimate(&self, sk: &[f64], i: usize, j: usize) -> f64 {
        let b = (self.cs_row.h(i) + self.cs_col.h(j)) % self.c;
        self.cs_row.s(i) * self.cs_col.s(j) * sk[b]
    }

    /// Full `n×n` reconstruction.
    pub fn decompress(&self, sk: &[f64]) -> Tensor {
        let mut out = Tensor::zeros(&[self.n, self.n]);
        for i in 0..self.n {
            for j in 0..self.n {
                out.set(&[i, j], self.estimate(sk, i, j));
            }
        }
        out
    }
}

/// Covariance through the MTS-sketched Kronecker product `A ⊗ Aᵀ`.
#[derive(Clone, Debug)]
pub struct MtsCovariance {
    pub n: usize,
    pub r: usize,
    kron: MtsKron,
}

impl MtsCovariance {
    pub fn new(n: usize, r: usize, m1: usize, m2: usize, seed: u64) -> Self {
        Self::with_repeat(n, r, m1, m2, seed, 0)
    }

    pub fn with_repeat(n: usize, r: usize, m1: usize, m2: usize, seed: u64, repeat: usize) -> Self {
        Self { n, r, kron: MtsKron::with_repeat(&[n, r], &[r, n], m1, m2, seed, repeat) }
    }

    /// Compression ratio (n·r)²/(m1·m2) — the Kronecker product this
    /// sketch stands in for is (nr)×(rn).
    pub fn compression_ratio(&self) -> f64 {
        self.kron.compression_ratio()
    }

    /// Sketch `A ⊗ Aᵀ` (never materialized).
    pub fn sketch(&self, a: &Tensor) -> Tensor {
        assert_eq!(a.dims(), &[self.n, self.r]);
        self.kron.compress(a, &a.transpose())
    }

    /// Estimate a single Kronecker entry `(A⊗Aᵀ)[ri+k, nk+j]
    /// = A[i,k]·Aᵀ[k,j]`.
    #[inline]
    pub fn estimate_kron_entry(&self, sk: &Tensor, i: usize, k: usize, j: usize) -> f64 {
        // A is the left operand with dims [n, r]; Aᵀ right with [r, n].
        // (A⊗Aᵀ)[r·i + k, n·k + j] ↔ kron index (p=i, h=k, q=k, g=j)
        self.kron.estimate(sk, i, k, k, j)
    }

    /// Estimate `(AAᵀ)[i,j] = Σ_k (A⊗Aᵀ)[r·i+k, n·k+j]`.
    pub fn estimate(&self, sk: &Tensor, i: usize, j: usize) -> f64 {
        (0..self.r).map(|k| self.estimate_kron_entry(sk, i, k, j)).sum()
    }

    /// Full `n×n` covariance reconstruction.
    pub fn decompress(&self, sk: &Tensor) -> Tensor {
        let mut out = Tensor::zeros(&[self.n, self.n]);
        for i in 0..self.n {
            for j in 0..self.n {
                out.set(&[i, j], self.estimate(sk, i, j));
            }
        }
        out
    }
}

/// Median-of-d covariance estimation, the protocol of Fig. 9 (paper uses
/// d = 300): run `d` independent sketches, take the entrywise median.
pub fn covariance_median_mts(
    a: &Tensor,
    m1: usize,
    m2: usize,
    d: usize,
    seed: u64,
) -> Tensor {
    let n = a.dims()[0];
    let r = a.dims()[1];
    let mut samples = vec![vec![0.0f64; d]; n * n];
    for rep in 0..d {
        let cov = MtsCovariance::with_repeat(n, r, m1, m2, seed, rep);
        let sk = cov.sketch(a);
        for i in 0..n {
            for j in 0..n {
                samples[i * n + j][rep] = cov.estimate(&sk, i, j);
            }
        }
    }
    let mut out = Tensor::zeros(&[n, n]);
    for (cell, s) in out.data_mut().iter_mut().zip(samples.iter_mut()) {
        *cell = median_inplace(s);
    }
    out
}

/// Median-of-d covariance estimation through the Pagh baseline.
pub fn covariance_median_pagh(a: &Tensor, c: usize, d: usize, seed: u64) -> Tensor {
    let n = a.dims()[0];
    let r = a.dims()[1];
    let mut samples = vec![vec![0.0f64; d]; n * n];
    for rep in 0..d {
        let cov = PaghCovariance::with_repeat(n, r, c, seed, rep);
        let sk = cov.sketch(a);
        for i in 0..n {
            for j in 0..n {
                samples[i * n + j][rep] = cov.estimate(&sk, i, j);
            }
        }
    }
    let mut out = Tensor::zeros(&[n, n]);
    for (cell, s) in out.data_mut().iter_mut().zip(samples.iter_mut()) {
        *cell = median_inplace(s);
    }
    out
}

/// The paper's Fig. 9 input: `A ∈ ℝ^{10×10}` uniform on [-1, 1] except
/// rows 2 and 9 (1-based) which are positively correlated.
pub fn figure9_matrix(rng: &mut crate::rng::Pcg64) -> Tensor {
    let mut a = Tensor::rand_uniform(&[10, 10], -1.0, 1.0, rng);
    // 1-based rows 2 and 9 → 0-based 1 and 8: row 8 = row 1 + small noise
    for j in 0..10 {
        let v = a.at2(1, j) + 0.1 * rng.normal();
        a.set(&[8, j], v);
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::tensor::{kron, rel_error};
    use crate::util::stats::{mean, variance};

    #[test]
    fn pagh_sketch_matches_direct_pair_hash() {
        let mut rng = Pcg64::new(1);
        let a = Tensor::randn(&[6, 4], &mut rng);
        let cov = PaghCovariance::new(6, 4, 8, 3);
        let sk = cov.sketch(&a);
        // direct: scatter (AAᵀ)_ij
        let aat = a.matmul(&a.transpose());
        let mut direct = vec![0.0; 8];
        for i in 0..6 {
            for j in 0..6 {
                direct[(cov.cs_row.h(i) + cov.cs_col.h(j)) % 8] +=
                    cov.cs_row.s(i) * cov.cs_col.s(j) * aat.at2(i, j);
            }
        }
        for (x, y) in sk.iter().zip(direct.iter()) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn pagh_estimate_unbiased() {
        let mut rng = Pcg64::new(2);
        let a = Tensor::randn(&[5, 3], &mut rng);
        let truth = a.matmul(&a.transpose()).at2(1, 3);
        let reps = 3000;
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let cov = PaghCovariance::with_repeat(5, 3, 6, 77, rep);
                cov.estimate(&cov.sketch(&a), 1, 3)
            })
            .collect();
        let m = mean(&est);
        let spread = (variance(&est) / reps as f64).sqrt();
        assert!((m - truth).abs() < 5.0 * spread.max(0.02), "{m} vs {truth}");
    }

    #[test]
    fn mts_kron_entry_identity() {
        // the summation identity (AAᵀ)_ij = Σ_k (A⊗Aᵀ)[ri+k, nk+j]
        // holds exactly on the dense Kronecker product
        let mut rng = Pcg64::new(3);
        let (n, r) = (4usize, 3usize);
        let a = Tensor::randn(&[n, r], &mut rng);
        let kp = kron(&a, &a.transpose());
        let aat = a.matmul(&a.transpose());
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0;
                for k in 0..r {
                    acc += kp.at2(r * i + k, n * k + j);
                }
                assert!((acc - aat.at2(i, j)).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn mts_covariance_unbiased() {
        let mut rng = Pcg64::new(4);
        let a = Tensor::randn(&[5, 3], &mut rng);
        let truth = a.matmul(&a.transpose()).at2(2, 4);
        let reps = 3000;
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let cov = MtsCovariance::with_repeat(5, 3, 6, 6, 13, rep);
                cov.estimate(&cov.sketch(&a), 2, 4)
            })
            .collect();
        let m = mean(&est);
        let spread = (variance(&est) / reps as f64).sqrt();
        assert!((m - truth).abs() < 5.0 * spread.max(0.03), "{m} vs {truth}");
    }

    #[test]
    fn median_estimation_beats_single_sketch() {
        let mut rng = Pcg64::new(5);
        let a = figure9_matrix(&mut rng);
        let aat = a.matmul(&a.transpose());
        let single = {
            let cov = MtsCovariance::new(10, 10, 8, 8, 9);
            cov.decompress(&cov.sketch(&a))
        };
        let med = covariance_median_mts(&a, 8, 8, 31, 9);
        let e_single = rel_error(&aat, &single);
        let e_med = rel_error(&aat, &med);
        assert!(e_med < e_single, "median {e_med} vs single {e_single}");
    }

    #[test]
    fn figure9_matrix_rows_correlated() {
        let mut rng = Pcg64::new(6);
        let a = figure9_matrix(&mut rng);
        let r1: Vec<f64> = (0..10).map(|j| a.at2(1, j)).collect();
        let r8: Vec<f64> = (0..10).map(|j| a.at2(8, j)).collect();
        let corr = crate::util::stats::correlation(&r1, &r8);
        assert!(corr > 0.9, "rows 2/9 should be strongly correlated, corr={corr}");
    }
}
