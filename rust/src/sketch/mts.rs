//! Multi-dimensional tensor sketch (MTS) — the paper's contribution
//! (§2.3, Algorithm 3). Later renamed Higher-order Count Sketch (HCS).
//!
//! `MTS(T)[t₁,…,t_N] = Σ_{h₁(i₁)=t₁,…,h_N(i_N)=t_N} s₁(i₁)⋯s_N(i_N)·T[i₁,…,i_N]`
//!
//! equivalently (Eq. 3) `MTS(T) = (S ∘ T)(H₁,…,H_N)` — the signed tensor
//! contracted with one-hot hash matrices along every mode. Recovery
//! (Eq. 4): `T̂[i…] = s₁(i₁)⋯s_N(i_N)·MTS(T)[h₁(i₁),…,h_N(i_N)]`.
//!
//! Two sketch paths are provided:
//! - [`MtsSketcher::sketch`] — fused scatter-accumulate, the fast path
//!   (single pass over `T`, no intermediates);
//! - [`MtsSketcher::sketch_contract`] — literal Eq. 3 via hash-matrix
//!   contractions (the structure the Pallas kernel mirrors); used to
//!   cross-validate the fused path and for the Table 4/5 op counting.

use crate::hash::{HashSeeds, ModeHash};
use crate::tensor::{multilinear, Tensor};

/// Sketches order-N tensors of shape `dims` into shape `sketch_dims`.
#[derive(Clone, Debug)]
pub struct MtsSketcher {
    pub dims: Vec<usize>,
    pub sketch_dims: Vec<usize>,
    modes: Vec<ModeHash>,
    /// materialized per-mode bucket tables (hot path)
    buckets: Vec<Vec<u32>>,
    /// materialized per-mode sign tables
    signs: Vec<Vec<f64>>,
}

impl MtsSketcher {
    /// Create a sketcher; `seed` determines all hash functions.
    pub fn new(dims: &[usize], sketch_dims: &[usize], seed: u64) -> Self {
        Self::with_repeat(dims, sketch_dims, seed, 0)
    }

    /// Variant used by median-of-d estimation: `repeat` selects an
    /// independent hash family from the same root seed.
    pub fn with_repeat(dims: &[usize], sketch_dims: &[usize], seed: u64, repeat: usize) -> Self {
        assert_eq!(dims.len(), sketch_dims.len(), "one sketch dim per mode");
        assert!(!dims.is_empty(), "order-0 tensors cannot be sketched");
        let seeds = HashSeeds::new(seed);
        let modes: Vec<ModeHash> = dims
            .iter()
            .zip(sketch_dims.iter())
            .enumerate()
            .map(|(k, (&n, &m))| ModeHash::new(n, m, seeds.seed_for(repeat, k)))
            .collect();
        let buckets = modes.iter().map(|m| m.bucket_table()).collect();
        let signs = modes.iter().map(|m| m.sign_table()).collect();
        Self { dims: dims.to_vec(), sketch_dims: sketch_dims.to_vec(), modes, buckets, signs }
    }

    /// Construct from explicit per-mode hashes (used when hashes must be
    /// shared across sketchers, e.g. the inner axis of
    /// [`crate::sketch::matmul::MtsMatmul`]).
    pub fn with_modes(dims: &[usize], sketch_dims: &[usize], modes: Vec<ModeHash>) -> Self {
        assert_eq!(dims.len(), sketch_dims.len());
        assert_eq!(modes.len(), dims.len());
        for (k, m) in modes.iter().enumerate() {
            assert_eq!(m.n, dims[k], "mode {k} input dim");
            assert_eq!(m.m, sketch_dims[k], "mode {k} sketch dim");
        }
        let buckets = modes.iter().map(|m| m.bucket_table()).collect();
        let signs = modes.iter().map(|m| m.sign_table()).collect();
        Self { dims: dims.to_vec(), sketch_dims: sketch_dims.to_vec(), modes, buckets, signs }
    }

    pub fn order(&self) -> usize {
        self.dims.len()
    }

    /// Per-mode hashes (used by the combine layers: Kron/Tucker/TT).
    pub fn mode(&self, k: usize) -> &ModeHash {
        &self.modes[k]
    }

    /// Compression ratio ∏n / ∏m.
    pub fn compression_ratio(&self) -> f64 {
        let n: usize = self.dims.iter().product();
        let m: usize = self.sketch_dims.iter().product();
        n as f64 / m as f64
    }

    /// Fused scatter path: one pass over `t`.
    pub fn sketch(&self, t: &Tensor) -> Tensor {
        assert_eq!(t.dims(), self.dims.as_slice(), "tensor dims mismatch");
        let mut out = Tensor::zeros(&self.sketch_dims);
        let od = out.data_mut();
        let data = t.data();
        let mut pos = 0usize;
        self.walk_fused(|off, sign| {
            od[off] += sign * data[pos];
            pos += 1;
        });
        out
    }

    /// Walk every input element position in row-major order, invoking
    /// `f(output offset, sign)` per element — the shared core of
    /// [`MtsSketcher::sketch`] and the batch path's fused tables.
    ///
    /// Maintains the per-mode index and the running offset/sign
    /// incrementally (profiled: recomputing them per element was the
    /// initial hot spot — see EXPERIMENTS.md §Perf).
    #[inline]
    fn walk_fused(&self, mut f: impl FnMut(usize, f64)) {
        let n = self.order();
        let total: usize = self.dims.iter().product();
        let mut idx = vec![0usize; n];
        // strides of the output tensor
        let mut out_strides = vec![1usize; n];
        for k in (0..n.saturating_sub(1)).rev() {
            out_strides[k] = out_strides[k + 1] * self.sketch_dims[k + 1];
        }
        // current per-mode contributions
        let mut off_parts: Vec<usize> =
            (0..n).map(|k| self.buckets[k][0] as usize * out_strides[k]).collect();
        let mut sign_parts: Vec<f64> = (0..n).map(|k| self.signs[k][0]).collect();
        let mut off: usize = off_parts.iter().sum();
        let mut sign: f64 = sign_parts.iter().product();
        for _ in 0..total {
            f(off, sign);
            // advance multi-index
            let mut k = n;
            loop {
                if k == 0 {
                    break;
                }
                k -= 1;
                idx[k] += 1;
                if idx[k] < self.dims[k] {
                    off -= off_parts[k];
                    sign /= sign_parts[k];
                    off_parts[k] = self.buckets[k][idx[k]] as usize * out_strides[k];
                    sign_parts[k] = self.signs[k][idx[k]];
                    off += off_parts[k];
                    sign *= sign_parts[k];
                    break;
                }
                idx[k] = 0;
                off -= off_parts[k];
                sign /= sign_parts[k];
                off_parts[k] = self.buckets[k][0] as usize * out_strides[k];
                sign_parts[k] = self.signs[k][0];
                off += off_parts[k];
                sign *= sign_parts[k];
            }
        }
    }

    /// Sketch a whole batch of same-shape tensors. The per-element
    /// (output offset, sign) walk — the expensive part of
    /// [`MtsSketcher::sketch`] — is materialized once into fused tables
    /// and replayed over every tensor, so the multi-index arithmetic
    /// and hash-table traversal amortize across the batch; each
    /// tensor's pass is then a tight gather-scatter.
    pub fn sketch_batch(&self, ts: &[&Tensor]) -> Vec<Tensor> {
        for (r, t) in ts.iter().enumerate() {
            assert_eq!(t.dims(), self.dims.as_slice(), "batch row {r}: tensor dims mismatch");
        }
        if ts.is_empty() {
            return Vec::new();
        }
        let (offs, sgns) = self.fused_tables();
        ts.iter()
            .map(|t| {
                let mut out = Tensor::zeros(&self.sketch_dims);
                let od = out.data_mut();
                for ((&off, &sign), &v) in offs.iter().zip(sgns.iter()).zip(t.data().iter()) {
                    od[off as usize] += sign * v;
                }
                out
            })
            .collect()
    }

    /// Materialize the fused per-element output offset and sign tables
    /// (row-major element order) that [`MtsSketcher::sketch_batch`]
    /// replays. Uses the same [`MtsSketcher::walk_fused`] core as
    /// `sketch`, so batch results are bit-identical to the
    /// single-tensor path.
    fn fused_tables(&self) -> (Vec<u32>, Vec<f64>) {
        let total: usize = self.dims.iter().product();
        let out_len: usize = self.sketch_dims.iter().product();
        assert!(out_len <= u32::MAX as usize, "sketch too large for u32 offsets");
        let mut offs = Vec::with_capacity(total);
        let mut sgns = Vec::with_capacity(total);
        self.walk_fused(|off, sign| {
            offs.push(off as u32);
            sgns.push(sign);
        });
        (offs, sgns)
    }

    /// Literal Eq. 3: `(S ∘ T)(H₁,…,H_N)` via hash-matrix contractions.
    pub fn sketch_contract(&self, t: &Tensor) -> Tensor {
        assert_eq!(t.dims(), self.dims.as_slice());
        let signed = self.apply_signs(t);
        let hs: Vec<Tensor> = self
            .modes
            .iter()
            .map(|m| Tensor::from_vec(m.hash_matrix(), &[m.n, m.m]))
            .collect();
        let refs: Vec<Option<&Tensor>> = hs.iter().map(Some).collect();
        multilinear(&signed, &refs)
    }

    /// `S ∘ T` where `S = s₁ ⊗ ⋯ ⊗ s_N`.
    pub fn apply_signs(&self, t: &Tensor) -> Tensor {
        let mut out = t.clone();
        let n = self.order();
        let mut idx = vec![0usize; n];
        for v in out.data_mut() {
            let mut sign = 1.0;
            for (k, &i) in idx.iter().enumerate() {
                sign *= self.signs[k][i];
            }
            *v *= sign;
            for k in (0..n).rev() {
                idx[k] += 1;
                if idx[k] < self.dims[k] {
                    break;
                }
                idx[k] = 0;
            }
        }
        out
    }

    /// Point estimate (Eq. 4, one entry): unbiased with variance
    /// ≤ ‖T‖_F² / ∏m (Theorem 2.1).
    #[inline]
    pub fn estimate(&self, sk: &Tensor, idx: &[usize]) -> f64 {
        debug_assert_eq!(idx.len(), self.order());
        let mut sidx = Vec::with_capacity(idx.len());
        let mut sign = 1.0;
        for (k, &i) in idx.iter().enumerate() {
            sidx.push(self.buckets[k][i] as usize);
            sign *= self.signs[k][i];
        }
        sign * sk.get(&sidx)
    }

    /// Full decompression (Eq. 4).
    pub fn decompress(&self, sk: &Tensor) -> Tensor {
        assert_eq!(sk.dims(), self.sketch_dims.as_slice(), "sketch dims mismatch");
        let mut out = Tensor::zeros(&self.dims);
        let n = self.order();
        let mut idx = vec![0usize; n];
        for v in out.data_mut() {
            *v = self.estimate(sk, &idx);
            for k in (0..n).rev() {
                idx[k] += 1;
                if idx[k] < self.dims[k] {
                    break;
                }
                idx[k] = 0;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::util::stats::{mean, variance};

    #[test]
    fn fused_matches_contract_path() {
        let mut rng = Pcg64::new(1);
        for (dims, sdims) in [
            (vec![6usize, 7], vec![3usize, 4]),
            (vec![4, 5, 6], vec![2, 3, 3]),
            (vec![3, 3, 3, 3], vec![2, 2, 2, 2]),
            (vec![9], vec![4]),
        ] {
            let t = Tensor::randn(&dims, &mut rng);
            let sk = MtsSketcher::new(&dims, &sdims, 42);
            let a = sk.sketch(&t);
            let b = sk.sketch_contract(&t);
            assert_eq!(a.dims(), b.dims());
            for (x, y) in a.data().iter().zip(b.data().iter()) {
                assert!((x - y).abs() < 1e-9, "dims {dims:?}");
            }
        }
    }

    #[test]
    fn sketch_batch_matches_single_sketches() {
        let mut rng = Pcg64::new(11);
        for (dims, sdims) in [
            (vec![6usize, 7], vec![3usize, 4]),
            (vec![4, 5, 6], vec![2, 3, 3]),
            (vec![9], vec![4]),
        ] {
            let ts: Vec<Tensor> = (0..5).map(|_| Tensor::randn(&dims, &mut rng)).collect();
            let refs: Vec<&Tensor> = ts.iter().collect();
            let sk = MtsSketcher::new(&dims, &sdims, 77);
            let batch = sk.sketch_batch(&refs);
            assert_eq!(batch.len(), 5);
            for (t, got) in ts.iter().zip(batch.iter()) {
                // fused tables replay the exact single-sketch walk
                assert_eq!(got.data(), sk.sketch(t).data(), "dims {dims:?}");
            }
        }
    }

    #[test]
    fn sketch_batch_empty_is_empty() {
        let sk = MtsSketcher::new(&[4, 4], &[2, 2], 0);
        assert!(sk.sketch_batch(&[]).is_empty());
    }

    #[test]
    fn sketch_shape_is_sketch_dims() {
        let mut rng = Pcg64::new(2);
        let t = Tensor::randn(&[10, 12, 8], &mut rng);
        let sk = MtsSketcher::new(&[10, 12, 8], &[4, 5, 3], 7);
        assert_eq!(sk.sketch(&t).dims(), &[4, 5, 3]);
        assert!((sk.compression_ratio() - (960.0 / 60.0)).abs() < 1e-12);
    }

    #[test]
    fn exact_recovery_when_hashes_injective() {
        // m == n doesn't guarantee injectivity, but a 1-sparse tensor is
        // always exactly recovered regardless of collisions.
        let dims = [8usize, 9];
        let sk = MtsSketcher::new(&dims, &[5, 4], 3);
        let mut t = Tensor::zeros(&dims);
        t.set(&[3, 7], -2.25);
        let rec = sk.decompress(&sk.sketch(&t));
        assert!((rec.get(&[3, 7]) + 2.25).abs() < 1e-12);
    }

    #[test]
    fn unbiasedness_theorem_2_1() {
        let dims = [6usize, 6];
        let mut rng = Pcg64::new(4);
        let t = Tensor::randn(&dims, &mut rng);
        let target = [2usize, 3];
        let truth = t.get(&target);
        let reps = 6000;
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let sk = MtsSketcher::new(&dims, &[3, 3], 10_000 + rep as u64);
                sk.estimate(&sk.sketch(&t), &target)
            })
            .collect();
        let m = mean(&est);
        let fro_sq = t.fro_norm().powi(2);
        let stderr = (fro_sq / 9.0 / reps as f64).sqrt();
        assert!((m - truth).abs() < 4.5 * stderr, "mean {m} vs {truth} ± {stderr}");
    }

    #[test]
    fn variance_bound_theorem_2_1() {
        // Theorem 2.1 states Var ≤ ‖T‖_F²/(m1·m2), but its proof sums
        // only over (i≠i*, j≠j*), silently dropping the same-row and
        // same-column collision terms which contribute at rates 1/m2 and
        // 1/m1 respectively. The *correct* bound (and what the empirical
        // variance matches — see EXPERIMENTS.md "Theorem 2.1 note") is
        //   Σ_{j≠j*} T[i*,j]²/m2 + Σ_{i≠i*} T[i,j*]²/m1
        //   + Σ_{i≠i*,j≠j*} T[i,j]²/(m1·m2).
        let dims = [8usize, 8];
        let sdims = [4usize, 4];
        let (i_star, j_star) = (1usize, 6usize);
        let mut rng = Pcg64::new(5);
        let t = Tensor::randn(&dims, &mut rng);
        let (m1, m2) = (sdims[0] as f64, sdims[1] as f64);
        let mut bound = 0.0;
        for i in 0..dims[0] {
            for j in 0..dims[1] {
                let v = t.get(&[i, j]).powi(2);
                bound += match (i == i_star, j == j_star) {
                    (true, true) => 0.0,
                    (true, false) => v / m2,
                    (false, true) => v / m1,
                    (false, false) => v / (m1 * m2),
                };
            }
        }
        let reps = 6000;
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let sk = MtsSketcher::new(&dims, &sdims, 77_000 + rep as u64);
                sk.estimate(&sk.sketch(&t), &[i_star, j_star])
            })
            .collect();
        let v = variance(&est);
        assert!(v < bound * 1.25, "var {v} vs corrected bound {bound}");
        // and the paper's (loose-in-the-other-direction) claim is indeed
        // violated here, which is why we test the corrected bound:
        let paper_bound = t.fro_norm().powi(2) / (m1 * m2);
        assert!(v > paper_bound, "if this fails the paper bound held after all");
    }

    #[test]
    fn third_order_roundtrip_error_reasonable() {
        // Fig 1 setting: sketch a third-order tensor, decompress, check
        // the error scales like the theory (not exact, but bounded).
        let mut rng = Pcg64::new(6);
        let t = Tensor::randn(&[8, 8, 8], &mut rng);
        let sk = MtsSketcher::new(&[8, 8, 8], &[6, 6, 6], 9);
        let rec = sk.decompress(&sk.sketch(&t));
        let err = crate::tensor::rel_error(&t, &rec);
        // single sketch of dense noise: error is O(1) but finite; the
        // median-of-d tests in estimate.rs check the real guarantee
        assert!(err.is_finite() && err < 3.0, "err={err}");
    }

    #[test]
    fn repeats_give_independent_sketches() {
        let dims = [10usize, 10];
        let mut rng = Pcg64::new(7);
        let t = Tensor::randn(&dims, &mut rng);
        let a = MtsSketcher::with_repeat(&dims, &[4, 4], 1, 0).sketch(&t);
        let b = MtsSketcher::with_repeat(&dims, &[4, 4], 1, 1).sketch(&t);
        assert_ne!(a.data(), b.data());
    }

    #[test]
    fn deterministic_given_seed() {
        let dims = [5usize, 6];
        let mut rng = Pcg64::new(8);
        let t = Tensor::randn(&dims, &mut rng);
        let a = MtsSketcher::new(&dims, &[3, 3], 55).sketch(&t);
        let b = MtsSketcher::new(&dims, &[3, 3], 55).sketch(&t);
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn linearity_of_sketch() {
        // MTS(aX + bY) = a·MTS(X) + b·MTS(Y) with the same hashes
        let dims = [7usize, 5];
        let mut rng = Pcg64::new(9);
        let x = Tensor::randn(&dims, &mut rng);
        let y = Tensor::randn(&dims, &mut rng);
        let sk = MtsSketcher::new(&dims, &[4, 3], 12);
        let lhs = sk.sketch(&x.scale(2.0).add(&y.scale(-3.0)));
        let rhs = sk.sketch(&x).scale(2.0).add(&sk.sketch(&y).scale(-3.0));
        for (a, b) in lhs.data().iter().zip(rhs.data().iter()) {
            assert!((a - b).abs() < 1e-9);
        }
    }
}
