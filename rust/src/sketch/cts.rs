//! Count-based tensor sketch (CTS) — the vector-space baseline the paper
//! compares against (§2.2, Algorithm 2): apply count sketch to every
//! fibre of the tensor along one mode, sharing the hash functions across
//! fibres. "The disadvantage is the ignorance of the connections between
//! fibres."

use super::cs::CsSketcher;
use crate::tensor::Tensor;

/// CTS: count-sketches the fibres along `mode` (default: last mode) from
/// length `n_mode` into `c` buckets; all other modes pass through.
#[derive(Clone, Debug)]
pub struct CtsSketcher {
    pub dims: Vec<usize>,
    pub mode: usize,
    pub c: usize,
    cs: CsSketcher,
}

impl CtsSketcher {
    pub fn new(dims: &[usize], mode: usize, c: usize, seed: u64) -> Self {
        assert!(mode < dims.len(), "mode {mode} out of range");
        let cs = CsSketcher::new(dims[mode], c, seed);
        Self { dims: dims.to_vec(), mode, c, cs }
    }

    /// Convenience: sketch along the last mode.
    pub fn new_last_mode(dims: &[usize], c: usize, seed: u64) -> Self {
        Self::new(dims, dims.len() - 1, c, seed)
    }

    /// Output dims: same as input with `dims[mode]` replaced by `c`.
    pub fn sketch_dims(&self) -> Vec<usize> {
        let mut d = self.dims.clone();
        d[self.mode] = self.c;
        d
    }

    pub fn compression_ratio(&self) -> f64 {
        self.dims[self.mode] as f64 / self.c as f64
    }

    /// Sketch every fibre along `mode` with the shared CS.
    pub fn sketch(&self, t: &Tensor) -> Tensor {
        assert_eq!(t.dims(), self.dims.as_slice(), "tensor dims mismatch");
        let unf = t.unfold(self.mode); // n_mode × rest
        let rest = unf.dims()[1];
        let n = self.dims[self.mode];
        let mut out_unf = Tensor::zeros(&[self.c, rest]);
        {
            let src = unf.data();
            let dst = out_unf.data_mut();
            for i in 0..n {
                let b = self.cs.h(i);
                let s = self.cs.s(i);
                let srow = &src[i * rest..(i + 1) * rest];
                let drow = &mut dst[b * rest..(b + 1) * rest];
                for (d, &x) in drow.iter_mut().zip(srow.iter()) {
                    *d += s * x;
                }
            }
        }
        Tensor::fold(&out_unf, self.mode, &self.sketch_dims())
    }

    /// Point estimate of `t[idx]`.
    pub fn estimate(&self, sk: &Tensor, idx: &[usize]) -> f64 {
        let mut sidx = idx.to_vec();
        let i = idx[self.mode];
        sidx[self.mode] = self.cs.h(i);
        self.cs.s(i) * sk.get(&sidx)
    }

    /// Full decompression (Algorithm 2, CTS-Decompress).
    pub fn decompress(&self, sk: &Tensor) -> Tensor {
        assert_eq!(sk.dims(), self.sketch_dims().as_slice());
        let unf = sk.unfold(self.mode); // c × rest
        let rest = unf.dims()[1];
        let n = self.dims[self.mode];
        let mut out_unf = Tensor::zeros(&[n, rest]);
        {
            let src = unf.data();
            let dst = out_unf.data_mut();
            for i in 0..n {
                let b = self.cs.h(i);
                let s = self.cs.s(i);
                let srow = &src[b * rest..(b + 1) * rest];
                let drow = &mut dst[i * rest..(i + 1) * rest];
                for (d, &x) in drow.iter_mut().zip(srow.iter()) {
                    *d = s * x;
                }
            }
        }
        Tensor::fold(&out_unf, self.mode, &self.dims)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::util::stats::mean;

    #[test]
    fn sketch_dims_and_ratio() {
        let cts = CtsSketcher::new(&[10, 20, 30], 2, 6, 1);
        assert_eq!(cts.sketch_dims(), vec![10, 20, 6]);
        assert!((cts.compression_ratio() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn matches_per_fibre_cs() {
        // CTS(T) fibre-by-fibre equals CS applied to each fibre
        let dims = [3usize, 4, 7];
        let mut rng = Pcg64::new(2);
        let t = Tensor::randn(&dims, &mut rng);
        let cts = CtsSketcher::new(&dims, 2, 4, 5);
        let sk = cts.sketch(&t);
        let cs = CsSketcher::new(7, 4, 5);
        for i in 0..3 {
            for j in 0..4 {
                let fibre: Vec<f64> = (0..7).map(|k| t.get(&[i, j, k])).collect();
                let want = cs.sketch(&fibre);
                for (k, &w) in want.iter().enumerate() {
                    assert!((sk.get(&[i, j, k]) - w).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn sketch_along_each_mode() {
        let dims = [4usize, 5, 6];
        let mut rng = Pcg64::new(3);
        let t = Tensor::randn(&dims, &mut rng);
        for mode in 0..3 {
            let cts = CtsSketcher::new(&dims, mode, 3, 7);
            let sk = cts.sketch(&t);
            let mut want = dims.to_vec();
            want[mode] = 3;
            assert_eq!(sk.dims(), want.as_slice());
            let rec = cts.decompress(&sk);
            assert_eq!(rec.dims(), dims.as_slice());
        }
    }

    #[test]
    fn unbiased_pointwise() {
        let dims = [5usize, 16];
        let mut rng = Pcg64::new(4);
        let t = Tensor::randn(&dims, &mut rng);
        let target = [2usize, 9];
        let truth = t.get(&target);
        let reps = 4000;
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let cts = CtsSketcher::new(&dims, 1, 4, 900 + rep as u64);
                cts.estimate(&cts.sketch(&t), &target)
            })
            .collect();
        let m = mean(&est);
        // per-fibre variance bound: ‖fibre‖²/c
        let fibre_norm_sq: f64 = (0..16).map(|j| t.get(&[2, j]).powi(2)).sum();
        let stderr = (fibre_norm_sq / 4.0 / reps as f64).sqrt();
        assert!((m - truth).abs() < 4.5 * stderr, "{m} vs {truth}");
    }

    #[test]
    fn decompress_matches_estimate() {
        let dims = [4usize, 6];
        let mut rng = Pcg64::new(5);
        let t = Tensor::randn(&dims, &mut rng);
        let cts = CtsSketcher::new_last_mode(&dims, 3, 11);
        let sk = cts.sketch(&t);
        let rec = cts.decompress(&sk);
        for i in 0..4 {
            for j in 0..6 {
                assert!((rec.get(&[i, j]) - cts.estimate(&sk, &[i, j])).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn one_sparse_fibre_exact() {
        let dims = [2usize, 10];
        let mut t = Tensor::zeros(&dims);
        t.set(&[1, 4], 9.5);
        let cts = CtsSketcher::new_last_mode(&dims, 5, 3);
        let rec = cts.decompress(&cts.sketch(&t));
        assert!((rec.get(&[1, 4]) - 9.5).abs() < 1e-12);
    }
}
