//! Inner products in sketch space: `⟨MTS(X), MTS(Y)⟩` is an unbiased
//! estimator of `⟨X, Y⟩` when both sketches share hashes — the
//! multi-dimensional analogue of the AMS/count-sketch inner-product
//! property, and the reason the sketched tensor-regression layer works
//! (`⟨W, A⟩ ≈ ⟨MTS(W), MTS(A)⟩`, §4.3).

use super::mts::MtsSketcher;
use crate::tensor::Tensor;

/// Estimate `⟨x, y⟩` from two sketches produced by the SAME sketcher.
pub fn inner_product_estimate(sx: &Tensor, sy: &Tensor) -> f64 {
    assert_eq!(sx.dims(), sy.dims(), "sketches must share shape");
    sx.data().iter().zip(sy.data().iter()).map(|(a, b)| a * b).sum()
}

/// Convenience: sketch both inputs and estimate their inner product.
pub fn sketched_inner_product(sk: &MtsSketcher, x: &Tensor, y: &Tensor) -> f64 {
    inner_product_estimate(&sk.sketch(x), &sk.sketch(y))
}

/// Squared-norm estimate `‖x‖² ≈ ‖MTS(x)‖²`.
pub fn sketched_norm_sq(sk: &MtsSketcher, x: &Tensor) -> f64 {
    let s = sk.sketch(x);
    s.data().iter().map(|v| v * v).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::util::stats::{mean, variance};

    fn dot(x: &Tensor, y: &Tensor) -> f64 {
        x.data().iter().zip(y.data().iter()).map(|(a, b)| a * b).sum()
    }

    #[test]
    fn inner_product_unbiased() {
        let dims = [8usize, 8];
        let mut rng = Pcg64::new(1);
        let x = Tensor::randn(&dims, &mut rng);
        let y = Tensor::randn(&dims, &mut rng);
        let truth = dot(&x, &y);
        let reps = 4000;
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let sk = MtsSketcher::with_repeat(&dims, &[4, 4], 7, rep);
                sketched_inner_product(&sk, &x, &y)
            })
            .collect();
        let m = mean(&est);
        let spread = (variance(&est) / reps as f64).sqrt();
        assert!((m - truth).abs() < 5.0 * spread.max(0.05), "{m} vs {truth}");
    }

    #[test]
    fn identical_hashes_required_for_meaning() {
        // different hash families give an estimate centered on 0, not ⟨x,y⟩
        let dims = [10usize, 10];
        let mut rng = Pcg64::new(2);
        let x = Tensor::randn(&dims, &mut rng);
        let reps = 1500;
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let a = MtsSketcher::with_repeat(&dims, &[4, 4], 1000 + rep as u64, 0);
                let b = MtsSketcher::with_repeat(&dims, &[4, 4], 9000 + rep as u64, 0);
                inner_product_estimate(&a.sketch(&x), &b.sketch(&x))
            })
            .collect();
        let m = mean(&est);
        let norm_sq = dot(&x, &x);
        assert!(m.abs() < 0.2 * norm_sq, "mismatched hashes should decorrelate: {m}");
    }

    #[test]
    fn norm_estimate_concentrates_with_size() {
        let dims = [12usize, 12];
        let mut rng = Pcg64::new(3);
        let x = Tensor::randn(&dims, &mut rng);
        let truth = dot(&x, &x);
        let spread_for = |m: usize| {
            let est: Vec<f64> = (0..400)
                .map(|rep| {
                    let sk = MtsSketcher::with_repeat(&dims, &[m, m], 5, rep);
                    sketched_norm_sq(&sk, &x)
                })
                .collect();
            (variance(&est).sqrt(), mean(&est))
        };
        let (s4, m4) = spread_for(4);
        let (s10, m10) = spread_for(10);
        // relative spread shrinks with sketch size; means near the truth
        assert!(s10 / m10 < s4 / m4, "{s4}/{m4} vs {s10}/{m10}");
        assert!((m10 - truth).abs() < 0.35 * truth, "{m10} vs {truth}");
    }

    #[test]
    fn trl_connection_weight_activation() {
        // the §4.3 identity used by the sketched TRL:
        // ⟨decompress(MTS(W)), A⟩ == ⟨MTS(W), MTS_scatter(A)⟩
        let dims = [6usize, 6];
        let mut rng = Pcg64::new(4);
        let w = Tensor::randn(&dims, &mut rng);
        let a = Tensor::randn(&dims, &mut rng);
        let sk = MtsSketcher::new(&dims, &[3, 3], 21);
        let lhs = dot(&sk.decompress(&sk.sketch(&w)), &a);
        let rhs = inner_product_estimate(&sk.sketch(&w), &sk.sketch(&a));
        assert!((lhs - rhs).abs() < 1e-9, "{lhs} vs {rhs}");
    }
}
