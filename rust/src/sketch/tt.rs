//! Sketching tensor-train tensors (§3.2, Algorithm 5, Theorems B.3/B.4).
//!
//! Third-order TT: `T[i1,i2,i3] = G1[i1,:]·G2[i2,:,:]·G3[i3,:]` with
//! `G1 ∈ ℝ^{n1×r1}`, `G2 ∈ ℝ^{n2×r1×r2}`, `G3 ∈ ℝ^{n3×r2}`.
//!
//! - [`CtsTt`] (Thm B.3 baseline): count-sketch each core along its
//!   ambient fibre (`n → c`); estimate an entry by contracting the
//!   decompressed rows, O(r²) per entry.
//! - [`MtsTt`] (Alg. 5): use the identity
//!   `reshape(T) = (G1 ⊗ G3) · reshape(G2)` — MTS-sketch `G1` and `G3`,
//!   combine with one FFT2 product (Lemma B.1), sketch `reshape(G2)`
//!   with the *matching composite row hash* on its `r1·r2` axis and a
//!   fresh column hash on `n2`, then multiply the two sketches. The
//!   paper's Alg. 5 leaves the second-level hash alignment implicit; we
//!   make it explicit, which is what makes the estimator unbiased (the
//!   same construction as the Tucker Eq. 8 path).

use super::cs::CsSketcher;
use super::mts::MtsSketcher;
use crate::decomp::TtTensor;
use crate::fft;
use crate::hash::HashSeeds;
use crate::tensor::Tensor;

// ---------------------------------------------------------------------
// CTS baseline (Theorem B.3)
// ---------------------------------------------------------------------

/// CS each TT core along its ambient mode into `c` buckets.
#[derive(Clone, Debug)]
pub struct CtsTt {
    pub dims: [usize; 3],
    pub ranks: [usize; 2],
    pub c: usize,
    cs1: CsSketcher,
    cs2: CsSketcher,
    cs3: CsSketcher,
}

impl CtsTt {
    pub fn new(dims: &[usize; 3], ranks: &[usize; 2], c: usize, seed: u64) -> Self {
        Self::with_repeat(dims, ranks, c, seed, 0)
    }

    pub fn with_repeat(
        dims: &[usize; 3],
        ranks: &[usize; 2],
        c: usize,
        seed: u64,
        repeat: usize,
    ) -> Self {
        let seeds = HashSeeds::new(seed);
        Self {
            dims: *dims,
            ranks: *ranks,
            c,
            cs1: CsSketcher::new(dims[0], c, seeds.seed_for(repeat, 0)),
            cs2: CsSketcher::new(dims[1], c, seeds.seed_for(repeat, 1)),
            cs3: CsSketcher::new(dims[2], c, seeds.seed_for(repeat, 2)),
        }
    }

    /// Sketch: `CS(G1) ∈ ℝ^{c×r1}`, `CS(G2) ∈ ℝ^{c×r1×r2}`,
    /// `CS(G3) ∈ ℝ^{c×r2}`.
    pub fn sketch(&self, t: &TtTensor) -> (Tensor, Tensor, Tensor) {
        let g1 = t.g1_matrix();
        let g2 = t.g2_tensor();
        let g3 = t.g3_matrix();
        assert_eq!(g1.dims(), &[self.dims[0], self.ranks[0]]);
        assert_eq!(g2.dims(), &[self.dims[1], self.ranks[0], self.ranks[1]]);
        assert_eq!(g3.dims(), &[self.dims[2], self.ranks[1]]);
        (
            sketch_rows(&self.cs1, &g1),
            sketch_rows(&self.cs2, &g2),
            sketch_rows(&self.cs3, &g3),
        )
    }

    /// Estimate one entry by contracting the decompressed core rows.
    pub fn estimate(&self, sk: &(Tensor, Tensor, Tensor), i: usize, j: usize, k: usize) -> f64 {
        let (r1, r2) = (self.ranks[0], self.ranks[1]);
        let (s1, s2, s3) = sk;
        let b1 = self.cs1.h(i);
        let b2 = self.cs2.h(j);
        let b3 = self.cs3.h(k);
        let sign = self.cs1.s(i) * self.cs2.s(j) * self.cs3.s(k);
        let mut acc = 0.0;
        for a in 0..r1 {
            let g1v = s1.get(&[b1, a]);
            if g1v == 0.0 {
                continue;
            }
            for b in 0..r2 {
                acc += g1v * s2.get(&[b2, a, b]) * s3.get(&[b3, b]);
            }
        }
        sign * acc
    }

    pub fn decompress(&self, sk: &(Tensor, Tensor, Tensor)) -> Tensor {
        let [n1, n2, n3] = self.dims;
        let mut out = Tensor::zeros(&[n1, n2, n3]);
        let mut pos = 0;
        let od = out.data_mut();
        for i in 0..n1 {
            for j in 0..n2 {
                for k in 0..n3 {
                    od[pos] = self.estimate(sk, i, j, k);
                    pos += 1;
                }
            }
        }
        out
    }

    /// Sketch memory in floats: c(r1 + r1r2 + r2).
    pub fn sketch_len(&self) -> usize {
        self.c * (self.ranks[0] + self.ranks[0] * self.ranks[1] + self.ranks[1])
    }
}

/// CS along the first (row/ambient) mode of a tensor, all trailing modes
/// pass through.
fn sketch_rows(cs: &CsSketcher, t: &Tensor) -> Tensor {
    let n = t.dims()[0];
    assert_eq!(n, cs.n);
    let rest: usize = t.dims()[1..].iter().product();
    let mut out_dims = t.dims().to_vec();
    out_dims[0] = cs.c;
    let mut out = Tensor::zeros(&out_dims);
    let od = out.data_mut();
    let src = t.data();
    for i in 0..n {
        let b = cs.h(i);
        let s = cs.s(i);
        for r in 0..rest {
            od[b * rest + r] += s * src[i * rest + r];
        }
    }
    out
}

// ---------------------------------------------------------------------
// CTS combined baseline (the Table 6 comparator)
// ---------------------------------------------------------------------

/// The paper's Table 6 CTS cost row — `O(nr² + cr² log c + c)` — is for
/// producing a *combined* sketch of T from the sketched cores via
/// Pagh's convolution sequence:
/// `CS(vec T) = Σ_{a,b} CS(G1[:,a]) * CS(G2[:,a,b]) * CS(G3[:,b])`
/// under the composite hash `h(i,j,k) = h1(i)+h2(j)+h3(k) mod c`.
#[derive(Clone, Debug)]
pub struct CtsTtCombined {
    pub dims: [usize; 3],
    pub ranks: [usize; 2],
    pub c: usize,
    cs1: CsSketcher,
    cs2: CsSketcher,
    cs3: CsSketcher,
}

impl CtsTtCombined {
    pub fn new(dims: &[usize; 3], ranks: &[usize; 2], c: usize, seed: u64) -> Self {
        Self::with_repeat(dims, ranks, c, seed, 0)
    }

    pub fn with_repeat(
        dims: &[usize; 3],
        ranks: &[usize; 2],
        c: usize,
        seed: u64,
        repeat: usize,
    ) -> Self {
        let seeds = HashSeeds::new(seed);
        Self {
            dims: *dims,
            ranks: *ranks,
            c,
            cs1: CsSketcher::new(dims[0], c, seeds.seed_for(repeat, 0)),
            cs2: CsSketcher::new(dims[1], c, seeds.seed_for(repeat, 1)),
            cs3: CsSketcher::new(dims[2], c, seeds.seed_for(repeat, 2)),
        }
    }

    /// Combined length-`c` count sketch of `vec(T)` (half-spectrum
    /// accumulation: one RFFT per sketched fibre, one IRFFT total).
    pub fn sketch(&self, t: &TtTensor) -> Vec<f64> {
        use crate::fft::Complex;
        let g1 = t.g1_matrix(); // n1 × r1
        let g2 = t.g2_tensor(); // n2 × r1 × r2
        let g3 = t.g3_matrix(); // n3 × r2
        let (r1, r2) = (self.ranks[0], self.ranks[1]);
        let c = self.c;
        let hc = c / 2 + 1;
        // half spectrum of the per-column CS of G1 / G3, per-(a,b) of G2
        let f1: Vec<Vec<Complex>> = (0..r1)
            .map(|a| crate::fft::rfft(&self.cs1.sketch(&g1.col(a))))
            .collect();
        let f3: Vec<Vec<Complex>> = (0..r2)
            .map(|b| crate::fft::rfft(&self.cs3.sketch(&g3.col(b))))
            .collect();
        let mut acc = vec![Complex::ZERO; hc];
        let mut fibre = vec![0.0f64; self.dims[1]];
        for a in 0..r1 {
            for b in 0..r2 {
                for (j, f) in fibre.iter_mut().enumerate() {
                    *f = g2.get(&[j, a, b]);
                }
                let f2 = crate::fft::rfft(&self.cs2.sketch(&fibre));
                for i in 0..hc {
                    acc[i] += f1[a][i] * f2[i] * f3[b][i];
                }
            }
        }
        crate::fft::irfft(&acc, c)
    }

    /// Point estimate under the composite hash.
    #[inline]
    pub fn estimate(&self, sk: &[f64], i: usize, j: usize, k: usize) -> f64 {
        let b = (self.cs1.h(i) + self.cs2.h(j) + self.cs3.h(k)) % self.c;
        self.cs1.s(i) * self.cs2.s(j) * self.cs3.s(k) * sk[b]
    }

    pub fn decompress(&self, sk: &[f64]) -> Tensor {
        let [n1, n2, n3] = self.dims;
        let mut out = Tensor::zeros(&[n1, n2, n3]);
        let mut pos = 0;
        let od = out.data_mut();
        for i in 0..n1 {
            for j in 0..n2 {
                for k in 0..n3 {
                    od[pos] = self.estimate(sk, i, j, k);
                    pos += 1;
                }
            }
        }
        out
    }

    pub fn sketch_len(&self) -> usize {
        self.c
    }
}

// ---------------------------------------------------------------------
// MTS variant (Algorithm 5)
// ---------------------------------------------------------------------

/// MTS of a third-order TT tensor. Final sketch: `m1 × m3` matrix;
/// memory O(m1·m3), computation O(nr² + m1m2 log(m1m2) + m1m2m3).
#[derive(Clone, Debug)]
pub struct MtsTt {
    pub dims: [usize; 3],
    pub ranks: [usize; 2],
    pub m1: usize,
    pub m2: usize,
    pub m3: usize,
    /// MTS for G1: rows n1→m1, cols r1→m2
    sk_g1: MtsSketcher,
    /// MTS for G3: rows n3→m1, cols r2→m2
    sk_g3: MtsSketcher,
    /// CS for G2's n2 axis → m3
    cs_n2: CsSketcher,
}

impl MtsTt {
    pub fn new(
        dims: &[usize; 3],
        ranks: &[usize; 2],
        m1: usize,
        m2: usize,
        m3: usize,
        seed: u64,
    ) -> Self {
        Self::with_repeat(dims, ranks, m1, m2, m3, seed, 0)
    }

    pub fn with_repeat(
        dims: &[usize; 3],
        ranks: &[usize; 2],
        m1: usize,
        m2: usize,
        m3: usize,
        seed: u64,
        repeat: usize,
    ) -> Self {
        let seeds = HashSeeds::new(seed);
        Self {
            dims: *dims,
            ranks: *ranks,
            m1,
            m2,
            m3,
            sk_g1: MtsSketcher::with_repeat(&[dims[0], ranks[0]], &[m1, m2], seed, 2 * repeat),
            sk_g3: MtsSketcher::with_repeat(
                &[dims[2], ranks[1]],
                &[m1, m2],
                seed ^ 0xDEAD_BEEF,
                2 * repeat + 1,
            ),
            cs_n2: CsSketcher::new(dims[1], m3, seeds.seed_for(repeat, 7)),
        }
    }

    /// Algorithm 5 Compress: K = MTS(G1)*MTS(G3) (FFT2), G2 sketched
    /// with the composite (r1,r2) hash and the n2 hash, P = K·G2'.
    pub fn sketch(&self, t: &TtTensor) -> Tensor {
        let g1 = t.g1_matrix();
        let g2 = t.g2_tensor(); // n2 × r1 × r2
        let g3 = t.g3_matrix();
        assert_eq!(g1.dims(), &[self.dims[0], self.ranks[0]], "G1 shape");
        assert_eq!(g3.dims(), &[self.dims[2], self.ranks[1]], "G3 shape");

        // 1. K = MTS(G1 ⊗ G3) via FFT2 combine (real half-spectrum path)
        let s1 = self.sk_g1.sketch(&g1);
        let s3 = self.sk_g3.sketch(&g3);
        let k = fft::circular_convolve2_real(s1.data(), s3.data(), self.m1, self.m2);

        // 2. G2' ∈ ℝ^{m2×m3}: rows (a,b) composite-hashed with the
        //    *column* hashes of G1/G3's sketches; cols j hashed by cs_n2
        let (r1, r2) = (self.ranks[0], self.ranks[1]);
        let n2 = self.dims[1];
        let col1 = self.sk_g1.mode(1);
        let col3 = self.sk_g3.mode(1);
        let mut g2s = vec![0.0; self.m2 * self.m3];
        for a in 0..r1 {
            let h_a = col1.h(a);
            let s_a = col1.s(a);
            for b in 0..r2 {
                let row = (h_a + col3.h(b)) % self.m2;
                let s_ab = s_a * col3.s(b);
                for j in 0..n2 {
                    let col = self.cs_n2.h(j);
                    g2s[row * self.m3 + col] +=
                        s_ab * self.cs_n2.s(j) * g2.get(&[j, a, b]);
                }
            }
        }

        // 3. P = K · G2' (compressed matrix multiplication in sketch
        //    space): m1×m2 · m2×m3
        let kt = Tensor::from_vec(k, &[self.m1, self.m2]);
        let g2t = Tensor::from_vec(g2s, &[self.m2, self.m3]);
        kt.matmul(&g2t)
    }

    /// Estimate `T[i1, i2, i3]`.
    #[inline]
    pub fn estimate(&self, p: &Tensor, i1: usize, i2: usize, i3: usize) -> f64 {
        let row1 = self.sk_g1.mode(0);
        let row3 = self.sk_g3.mode(0);
        let r = (row1.h(i1) + row3.h(i3)) % self.m1;
        let c = self.cs_n2.h(i2);
        row1.s(i1) * row3.s(i3) * self.cs_n2.s(i2) * p.get(&[r, c])
    }

    pub fn decompress(&self, p: &Tensor) -> Tensor {
        let [n1, n2, n3] = self.dims;
        let mut out = Tensor::zeros(&[n1, n2, n3]);
        let mut pos = 0;
        let od = out.data_mut();
        for i1 in 0..n1 {
            for i2 in 0..n2 {
                for i3 in 0..n3 {
                    od[pos] = self.estimate(p, i1, i2, i3);
                    pos += 1;
                }
            }
        }
        out
    }

    /// Final sketch memory in floats.
    pub fn sketch_len(&self) -> usize {
        self.m1 * self.m3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::util::stats::{mean, median, variance};

    fn small_tt(seed: u64) -> TtTensor {
        let mut rng = Pcg64::new(seed);
        TtTensor::random(&[6, 5, 6], &[2, 2], &mut rng)
    }

    #[test]
    fn cts_tt_estimate_unbiased() {
        let tt = small_tt(1);
        let dense = tt.reconstruct();
        let truth = dense.get(&[2, 3, 4]);
        let reps = 2500;
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let s = CtsTt::with_repeat(&[6, 5, 6], &[2, 2], 4, 99, rep);
                s.estimate(&s.sketch(&tt), 2, 3, 4)
            })
            .collect();
        let m = mean(&est);
        let spread = (variance(&est) / reps as f64).sqrt();
        assert!((m - truth).abs() < 5.0 * spread.max(0.02), "{m} vs {truth}");
    }

    #[test]
    fn mts_tt_estimate_unbiased() {
        let tt = small_tt(2);
        let dense = tt.reconstruct();
        let truth = dense.get(&[5, 1, 0]);
        let reps = 2500;
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let s = MtsTt::with_repeat(&[6, 5, 6], &[2, 2], 6, 6, 4, 55, rep);
                s.estimate(&s.sketch(&tt), 5, 1, 0)
            })
            .collect();
        let m = mean(&est);
        let spread = (variance(&est) / reps as f64).sqrt();
        assert!((m - truth).abs() < 5.0 * spread.max(0.02), "{m} vs {truth}");
    }

    #[test]
    fn mts_tt_error_shrinks_with_sketch() {
        let tt = small_tt(3);
        let dense = tt.reconstruct();
        let err_for = |m1: usize, m3: usize| {
            let errs: Vec<f64> = (0..5)
                .map(|rep| {
                    let s = MtsTt::with_repeat(&[6, 5, 6], &[2, 2], m1, 8, m3, 7, rep);
                    crate::tensor::rel_error(&dense, &s.decompress(&s.sketch(&tt)))
                })
                .collect();
            median(&errs)
        };
        let e_small = err_for(4, 3);
        let e_big = err_for(64, 5);
        assert!(e_big < e_small, "small {e_small} vs big {e_big}");
    }

    #[test]
    fn cts_tt_exact_when_no_collisions() {
        // With c large, the per-core hashes are likely injective on the
        // used indices; then estimates equal exact contraction values.
        let tt = small_tt(4);
        let dense = tt.reconstruct();
        // find a repeat whose hashes are injective for all three cores
        'outer: for rep in 0..50 {
            let s = CtsTt::with_repeat(&[6, 5, 6], &[2, 2], 64, 123, rep);
            for cs in [&s.cs1, &s.cs2, &s.cs3] {
                let mut seen = std::collections::HashSet::new();
                for i in 0..cs.n {
                    if !seen.insert(cs.h(i)) {
                        continue 'outer;
                    }
                }
            }
            let rec = s.decompress(&s.sketch(&tt));
            assert!(crate::tensor::rel_error(&dense, &rec) < 1e-9);
            return;
        }
        panic!("no injective hash family found in 50 repeats (c=64, n=6)");
    }

    #[test]
    fn cts_combined_matches_direct_composite_scatter() {
        let tt = small_tt(7);
        let dense = tt.reconstruct();
        let s = CtsTtCombined::new(&[6, 5, 6], &[2, 2], 16, 3);
        let sk = s.sketch(&tt);
        let mut direct = vec![0.0f64; 16];
        for i in 0..6 {
            for j in 0..5 {
                for k in 0..6 {
                    let b = (s.cs1.h(i) + s.cs2.h(j) + s.cs3.h(k)) % 16;
                    direct[b] +=
                        s.cs1.s(i) * s.cs2.s(j) * s.cs3.s(k) * dense.get(&[i, j, k]);
                }
            }
        }
        for (a, b) in sk.iter().zip(direct.iter()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn cts_combined_unbiased() {
        let tt = small_tt(8);
        let dense = tt.reconstruct();
        let truth = dense.get(&[1, 2, 3]);
        let reps = 2500;
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let s = CtsTtCombined::with_repeat(&[6, 5, 6], &[2, 2], 12, 44, rep);
                s.estimate(&s.sketch(&tt), 1, 2, 3)
            })
            .collect();
        let m = mean(&est);
        let spread = (variance(&est) / reps as f64).sqrt();
        assert!((m - truth).abs() < 5.0 * spread.max(0.02), "{m} vs {truth}");
    }

    #[test]
    fn sketch_lens() {
        let cts = CtsTt::new(&[6, 5, 6], &[2, 2], 4, 0);
        assert_eq!(cts.sketch_len(), 4 * (2 + 4 + 2));
        let mts = MtsTt::new(&[6, 5, 6], &[2, 2], 6, 8, 4, 0);
        assert_eq!(mts.sketch_len(), 24);
    }
}
