//! Sketched Kronecker products (§2.4, Appendix A.1/B.1) — the paper's
//! flagship example of computing a tensor operation directly in sketch
//! space.
//!
//! - [`MtsKron`]: `MTS(A ⊗ B) = MTS(A) * MTS(B)` (2-D circular
//!   convolution; Lemma B.1), evaluated as
//!   `IFFT2(FFT2(MTS(A)) ∘ FFT2(MTS(B)))` in O(n² + m² log m) — never
//!   materializing the n²×n² product (Fig. 6).
//! - [`CtsKron`]: the baseline (Fig. 5) — count-sketch each row-pair
//!   outer product via Pagh's FFT trick, O(n²(n + c log c)).
//!
//! Compression ratios follow §4.1: for `C ∈ ℝ^{ab×de}`,
//! CTS(C) ∈ ℝ^{ab×c} has ratio `de/c`; MTS(C) ∈ ℝ^{m1×m2} has ratio
//! `ab·de/(m1·m2)`.

use super::cs::CsSketcher;
use super::mts::MtsSketcher;
use crate::fft::{self, circular_convolve2_real, Complex};
use crate::tensor::Tensor;
use std::collections::HashMap;

/// MTS sketch of `A ⊗ B` computed entirely in sketch space.
#[derive(Clone, Debug)]
pub struct MtsKron {
    /// sketcher for A ∈ ℝ^{n1×n2}
    pub ska: MtsSketcher,
    /// sketcher for B ∈ ℝ^{n3×n4}
    pub skb: MtsSketcher,
}

impl MtsKron {
    /// Both inputs are sketched to the same `m1 × m2` so the combine is
    /// a same-shape convolution.
    pub fn new(a_dims: &[usize; 2], b_dims: &[usize; 2], m1: usize, m2: usize, seed: u64) -> Self {
        Self::with_repeat(a_dims, b_dims, m1, m2, seed, 0)
    }

    pub fn with_repeat(
        a_dims: &[usize; 2],
        b_dims: &[usize; 2],
        m1: usize,
        m2: usize,
        seed: u64,
        repeat: usize,
    ) -> Self {
        // derive disjoint seeds for the two inputs from one root
        let ska = MtsSketcher::with_repeat(a_dims, &[m1, m2], seed, 2 * repeat);
        let skb = MtsSketcher::with_repeat(b_dims, &[m1, m2], seed ^ 0x5bd1_e995, 2 * repeat + 1);
        Self { ska, skb }
    }

    pub fn m1(&self) -> usize {
        self.ska.sketch_dims[0]
    }

    pub fn m2(&self) -> usize {
        self.ska.sketch_dims[1]
    }

    /// Dims of the (never materialized) Kronecker product.
    pub fn kron_dims(&self) -> [usize; 2] {
        [
            self.ska.dims[0] * self.skb.dims[0],
            self.ska.dims[1] * self.skb.dims[1],
        ]
    }

    /// Compression ratio `ab·de/(m1·m2)`.
    pub fn compression_ratio(&self) -> f64 {
        let [r, c] = self.kron_dims();
        (r * c) as f64 / (self.m1() * self.m2()) as f64
    }

    /// Algorithm 4 Compress-KP: sketch both inputs, combine via FFT2.
    pub fn compress(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let sa = self.ska.sketch(a);
        let sb = self.skb.sketch(b);
        self.combine(&sa, &sb)
    }

    /// Combine pre-computed input sketches (the hot path the coordinator
    /// batches): `IFFT2(FFT2(sa) ∘ FFT2(sb))`, evaluated on the
    /// real-input half-spectrum path (sketches are real, so conjugate
    /// symmetry halves the transform work — see `fft::real`).
    pub fn combine(&self, sa: &Tensor, sb: &Tensor) -> Tensor {
        let (m1, m2) = (self.m1(), self.m2());
        let p = circular_convolve2_real(sa.data(), sb.data(), m1, m2);
        Tensor::from_vec(p, &[m1, m2])
    }

    /// Combine a whole batch of sketch pairs. One forward RFFT2 is run
    /// per *distinct* operand (repeats within the batch — e.g. one A
    /// combined against many Bs — reuse the cached spectrum), and all
    /// transforms share the thread-local plans and scratch.
    pub fn combine_batch(&self, pairs: &[(&Tensor, &Tensor)]) -> Vec<Tensor> {
        let (m1, m2) = (self.m1(), self.m2());
        let hc = m2 / 2 + 1;
        // spectra cache keyed by operand identity (data pointer)
        let mut spectra: Vec<Vec<Complex>> = Vec::new();
        let mut index: HashMap<usize, usize> = HashMap::new();
        let mut spectrum_of = |t: &Tensor, spectra: &mut Vec<Vec<Complex>>| -> usize {
            assert_eq!(t.dims(), &[m1, m2], "combine_batch operand dims");
            let key = t.data().as_ptr() as usize;
            *index.entry(key).or_insert_with(|| {
                spectra.push(fft::rfft2(t.data(), m1, m2));
                spectra.len() - 1
            })
        };
        let mut prod = vec![Complex::ZERO; m1 * hc];
        pairs
            .iter()
            .map(|&(a, b)| {
                let ia = spectrum_of(a, &mut spectra);
                let ib = spectrum_of(b, &mut spectra);
                let (fa, fb) = (&spectra[ia], &spectra[ib]);
                for ((p, x), y) in prod.iter_mut().zip(fa.iter()).zip(fb.iter()) {
                    *p = *x * *y;
                }
                Tensor::from_vec(fft::irfft2(&prod, m1, m2), &[m1, m2])
            })
            .collect()
    }

    /// Combine when the RFFT2 of one side is cached (see
    /// [`MtsKron::fft_of_sketch`]); saves one forward transform per call.
    pub fn combine_with_cached(&self, fa: &[Complex], sb: &Tensor) -> Tensor {
        let (m1, m2) = (self.m1(), self.m2());
        let mut fb = fft::rfft2(sb.data(), m1, m2);
        for (y, x) in fb.iter_mut().zip(fa.iter()) {
            *y = *y * *x;
        }
        let p = fft::irfft2(&fb, m1, m2);
        Tensor::from_vec(p, &[m1, m2])
    }

    /// Forward RFFT2 of an input sketch, for reuse across combines.
    /// Returns the `m1 × (m2/2 + 1)` half-spectrum slab (the layout
    /// [`fft::rfft2`] produces); treat it as opaque and feed it back to
    /// [`MtsKron::combine_with_cached`].
    pub fn fft_of_sketch(&self, s: &Tensor) -> Vec<Complex> {
        fft::rfft2(s.data(), self.m1(), self.m2())
    }

    /// Estimate one entry `(A⊗B)[n3·p + h, n4·q + g]` from the combined
    /// sketch (recovery map of Lemma B.1).
    #[inline]
    pub fn estimate(&self, p_sk: &Tensor, p: usize, q: usize, h: usize, g: usize) -> f64 {
        let (m1, m2) = (self.m1(), self.m2());
        let ha = self.ska.mode(0);
        let hb = self.skb.mode(0);
        let wa = self.ska.mode(1);
        let wb = self.skb.mode(1);
        let k = (ha.h(p) + hb.h(h)) % m1;
        let l = (wa.h(q) + wb.h(g)) % m2;
        ha.s(p) * wa.s(q) * hb.s(h) * wb.s(g) * p_sk.get(&[k, l])
    }

    /// Algorithm 4 Decompress-KP: full reconstruction of `A ⊗ B`.
    pub fn decompress(&self, p_sk: &Tensor) -> Tensor {
        let (n1, n2) = (self.ska.dims[0], self.ska.dims[1]);
        let (n3, n4) = (self.skb.dims[0], self.skb.dims[1]);
        let (m1, m2) = (self.m1(), self.m2());
        // materialize hash/sign tables once (profiled; see §Perf)
        let ha: Vec<usize> = (0..n1).map(|i| self.ska.mode(0).h(i)).collect();
        let sa: Vec<f64> = (0..n1).map(|i| self.ska.mode(0).s(i)).collect();
        let wa_h: Vec<usize> = (0..n2).map(|i| self.ska.mode(1).h(i)).collect();
        let wa_s: Vec<f64> = (0..n2).map(|i| self.ska.mode(1).s(i)).collect();
        let hb: Vec<usize> = (0..n3).map(|i| self.skb.mode(0).h(i)).collect();
        let sb: Vec<f64> = (0..n3).map(|i| self.skb.mode(0).s(i)).collect();
        let wb_h: Vec<usize> = (0..n4).map(|i| self.skb.mode(1).h(i)).collect();
        let wb_s: Vec<f64> = (0..n4).map(|i| self.skb.mode(1).s(i)).collect();
        let cols = n2 * n4;
        let mut out = Tensor::zeros(&[n1 * n3, cols]);
        let od = out.data_mut();
        for p in 0..n1 {
            for h in 0..n3 {
                let k = (ha[p] + hb[h]) % m1;
                let s_row = sa[p] * sb[h];
                let row = (p * n3 + h) * cols;
                for q in 0..n2 {
                    let sq = s_row * wa_s[q];
                    for g in 0..n4 {
                        let l = (wa_h[q] + wb_h[g]) % m2;
                        od[row + q * n4 + g] = sq * wb_s[g] * p_sk.get(&[k, l]);
                    }
                }
            }
        }
        out
    }
}

/// CTS baseline for Kronecker sketching (Fig. 5): sketch each row-pair
/// outer product `A[p,:] ⊗ B[h,:]` with Pagh's method; output
/// `(n1·n3) × c`.
#[derive(Clone, Debug)]
pub struct CtsKron {
    /// CS over A's column index (length n2)
    pub su: CsSketcher,
    /// CS over B's column index (length n4)
    pub sv: CsSketcher,
    pub a_dims: [usize; 2],
    pub b_dims: [usize; 2],
}

impl CtsKron {
    pub fn new(a_dims: &[usize; 2], b_dims: &[usize; 2], c: usize, seed: u64) -> Self {
        Self::with_repeat(a_dims, b_dims, c, seed, 0)
    }

    pub fn with_repeat(
        a_dims: &[usize; 2],
        b_dims: &[usize; 2],
        c: usize,
        seed: u64,
        repeat: usize,
    ) -> Self {
        let seeds = crate::hash::HashSeeds::new(seed);
        Self {
            su: CsSketcher::new(a_dims[1], c, seeds.seed_for(repeat, 0)),
            sv: CsSketcher::new(b_dims[1], c, seeds.seed_for(repeat, 1)),
            a_dims: *a_dims,
            b_dims: *b_dims,
        }
    }

    pub fn c(&self) -> usize {
        self.su.c
    }

    /// Compression ratio `de/c` (columns only, per §4.1).
    pub fn compression_ratio(&self) -> f64 {
        (self.a_dims[1] * self.b_dims[1]) as f64 / self.c() as f64
    }

    /// Sketch `A ⊗ B`: for every row pair (p, h),
    /// `out[(p,h),:] = IFFT(FFT(CS(A[p,:])) ∘ FFT(CS(B[h,:])))`.
    /// Runs on the real half-spectrum path: one RFFT per input row
    /// (cached spectra), one half-size product + IRFFT per pair.
    pub fn compress(&self, a: &Tensor, b: &Tensor) -> Tensor {
        assert_eq!(a.dims(), &self.a_dims);
        assert_eq!(b.dims(), &self.b_dims);
        let c = self.c();
        let (n1, n3) = (self.a_dims[0], self.b_dims[0]);
        // half spectrum of each row sketch, computed once per row
        let fa: Vec<Vec<Complex>> =
            (0..n1).map(|p| fft::rfft(&self.su.sketch(a.row(p)))).collect();
        let fb: Vec<Vec<Complex>> =
            (0..n3).map(|h| fft::rfft(&self.sv.sketch(b.row(h)))).collect();
        let plan = fft::real_plan(c);
        let hc = plan.spectrum_len();
        let mut out = Tensor::zeros(&[n1 * n3, c]);
        let od = out.data_mut();
        let mut buf = vec![Complex::ZERO; hc];
        for p in 0..n1 {
            for h in 0..n3 {
                for (i, b) in buf.iter_mut().enumerate() {
                    *b = fa[p][i] * fb[h][i];
                }
                let row = (p * n3 + h) * c;
                plan.inverse(&buf, &mut od[row..row + c]);
            }
        }
        out
    }

    /// Estimate `(A⊗B)[n3·p + h, n4·q + g]`.
    #[inline]
    pub fn estimate(&self, sk: &Tensor, p: usize, q: usize, h: usize, g: usize) -> f64 {
        let n3 = self.b_dims[0];
        let k = (self.su.h(q) + self.sv.h(g)) % self.c();
        self.su.s(q) * self.sv.s(g) * sk.get(&[p * n3 + h, k])
    }

    /// Full reconstruction of `A ⊗ B`.
    pub fn decompress(&self, sk: &Tensor) -> Tensor {
        let (n1, n2) = (self.a_dims[0], self.a_dims[1]);
        let (n3, n4) = (self.b_dims[0], self.b_dims[1]);
        let c = self.c();
        let hq: Vec<usize> = (0..n2).map(|q| self.su.h(q)).collect();
        let sq: Vec<f64> = (0..n2).map(|q| self.su.s(q)).collect();
        let hg: Vec<usize> = (0..n4).map(|g| self.sv.h(g)).collect();
        let sg: Vec<f64> = (0..n4).map(|g| self.sv.s(g)).collect();
        let cols = n2 * n4;
        let mut out = Tensor::zeros(&[n1 * n3, cols]);
        let od = out.data_mut();
        for p in 0..n1 {
            for h in 0..n3 {
                let srow = sk.row(p * n3 + h);
                let row = (p * n3 + h) * cols;
                for q in 0..n2 {
                    for g in 0..n4 {
                        od[row + q * n4 + g] = sq[q] * sg[g] * srow[(hq[q] + hg[g]) % c];
                    }
                }
            }
        }
        out
    }
}

/// N-ary sketched Kronecker product `MTS(A₁ ⊗ A₂ ⊗ ⋯ ⊗ A_N)` — the
/// Lemma B.1 identity is associative, so all factor sketches are
/// combined with a single pass of 2-D spectral products:
/// `IFFT2(∏ₖ FFT2(MTS(Aₖ)))`. This is the primitive the Tucker path
/// (Eq. 8) uses with N = tensor order; exposed publicly for multi-way
/// feature-combination workloads (e.g. trilinear pooling).
#[derive(Clone, Debug)]
pub struct MtsKronN {
    pub sketchers: Vec<MtsSketcher>,
}

impl MtsKronN {
    /// `dims[k]` is the shape of factor k; all share the sketch size.
    pub fn new(dims: &[[usize; 2]], m1: usize, m2: usize, seed: u64) -> Self {
        Self::with_repeat(dims, m1, m2, seed, 0)
    }

    pub fn with_repeat(
        dims: &[[usize; 2]],
        m1: usize,
        m2: usize,
        seed: u64,
        repeat: usize,
    ) -> Self {
        assert!(dims.len() >= 2, "need at least two factors");
        let sketchers = dims
            .iter()
            .enumerate()
            .map(|(k, d)| {
                MtsSketcher::with_repeat(
                    d,
                    &[m1, m2],
                    seed ^ (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    repeat,
                )
            })
            .collect();
        Self { sketchers }
    }

    pub fn m1(&self) -> usize {
        self.sketchers[0].sketch_dims[0]
    }

    pub fn m2(&self) -> usize {
        self.sketchers[0].sketch_dims[1]
    }

    /// Sketch every factor and combine in the frequency domain (half
    /// spectra — the N-ary product is accumulated on `m1 × (m2/2 + 1)`
    /// slabs and inverted once).
    pub fn compress(&self, factors: &[&Tensor]) -> Tensor {
        assert_eq!(factors.len(), self.sketchers.len());
        let (m1, m2) = (self.m1(), self.m2());
        let mut freq: Option<Vec<Complex>> = None;
        for (sk, f) in self.sketchers.iter().zip(factors.iter()) {
            let s = sk.sketch(f);
            let fs = fft::rfft2(s.data(), m1, m2);
            freq = Some(match freq {
                None => fs,
                Some(mut acc) => {
                    for (a, b) in acc.iter_mut().zip(fs.iter()) {
                        *a = *a * *b;
                    }
                    acc
                }
            });
        }
        let out = fft::irfft2(&freq.unwrap(), m1, m2);
        Tensor::from_vec(out, &[m1, m2])
    }

    /// Estimate one entry of the product; `rows[k]`/`cols[k]` index
    /// factor k.
    pub fn estimate(&self, p: &Tensor, rows: &[usize], cols: &[usize]) -> f64 {
        assert_eq!(rows.len(), self.sketchers.len());
        assert_eq!(cols.len(), self.sketchers.len());
        let (m1, m2) = (self.m1(), self.m2());
        let mut r = 0usize;
        let mut c = 0usize;
        let mut sign = 1.0;
        for (k, sk) in self.sketchers.iter().enumerate() {
            r += sk.mode(0).h(rows[k]);
            c += sk.mode(1).h(cols[k]);
            sign *= sk.mode(0).s(rows[k]) * sk.mode(1).s(cols[k]);
        }
        sign * p.get(&[r % m1, c % m2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::tensor::{kron, rel_error};
    use crate::util::stats::mean;

    /// Direct MTS of the materialized Kronecker product using the
    /// *derived* hashes of Lemma B.1 — ground truth for the combine.
    fn direct_mts_of_kron(mk: &MtsKron, a: &Tensor, b: &Tensor) -> Tensor {
        let (n1, n2) = (mk.ska.dims[0], mk.ska.dims[1]);
        let (n3, n4) = (mk.skb.dims[0], mk.skb.dims[1]);
        let (m1, m2) = (mk.m1(), mk.m2());
        let mut out = Tensor::zeros(&[m1, m2]);
        for p in 0..n1 {
            for q in 0..n2 {
                for h in 0..n3 {
                    for g in 0..n4 {
                        let k = (mk.ska.mode(0).h(p) + mk.skb.mode(0).h(h)) % m1;
                        let l = (mk.ska.mode(1).h(q) + mk.skb.mode(1).h(g)) % m2;
                        let s = mk.ska.mode(0).s(p)
                            * mk.ska.mode(1).s(q)
                            * mk.skb.mode(0).s(h)
                            * mk.skb.mode(1).s(g);
                        let v = out.get(&[k, l]) + s * a.at2(p, q) * b.at2(h, g);
                        out.set(&[k, l], v);
                    }
                }
            }
        }
        out
    }

    #[test]
    fn lemma_b1_combine_equals_direct_sketch() {
        let mut rng = Pcg64::new(1);
        let a = Tensor::randn(&[4, 5], &mut rng);
        let b = Tensor::randn(&[3, 6], &mut rng);
        let mk = MtsKron::new(&[4, 5], &[3, 6], 7, 8, 99);
        let combined = mk.compress(&a, &b);
        let direct = direct_mts_of_kron(&mk, &a, &b);
        assert!(
            rel_error(&direct, &combined) < 1e-9,
            "err={}",
            rel_error(&direct, &combined)
        );
    }

    #[test]
    fn mts_kron_estimate_unbiased() {
        let mut rng = Pcg64::new(2);
        let a = Tensor::randn(&[4, 4], &mut rng);
        let b = Tensor::randn(&[4, 4], &mut rng);
        let truth = a.at2(1, 2) * b.at2(3, 0);
        let reps = 3000;
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let mk = MtsKron::with_repeat(&[4, 4], &[4, 4], 6, 6, 1234, rep);
                let p = mk.compress(&a, &b);
                mk.estimate(&p, 1, 2, 3, 0)
            })
            .collect();
        let m = mean(&est);
        let fro = kron(&a, &b).fro_norm();
        let stderr = (fro * fro / 36.0 / reps as f64).sqrt();
        assert!((m - truth).abs() < 5.0 * stderr, "{m} vs {truth} (stderr {stderr})");
    }

    #[test]
    fn mts_decompress_matches_estimates_and_shape() {
        let mut rng = Pcg64::new(3);
        let a = Tensor::randn(&[3, 4], &mut rng);
        let b = Tensor::randn(&[2, 5], &mut rng);
        let mk = MtsKron::new(&[3, 4], &[2, 5], 5, 7, 17);
        let p = mk.compress(&a, &b);
        let rec = mk.decompress(&p);
        assert_eq!(rec.dims(), &[6, 20]);
        for pp in 0..3 {
            for q in 0..4 {
                for h in 0..2 {
                    for g in 0..5 {
                        let want = mk.estimate(&p, pp, q, h, g);
                        let got = rec.at2(pp * 2 + h, q * 5 + g);
                        assert!((want - got).abs() < 1e-12);
                    }
                }
            }
        }
    }

    #[test]
    fn cts_kron_matches_direct_pair_hash_sketch() {
        let mut rng = Pcg64::new(4);
        let a = Tensor::randn(&[3, 5], &mut rng);
        let b = Tensor::randn(&[2, 4], &mut rng);
        let ck = CtsKron::new(&[3, 5], &[2, 4], 8, 7);
        let sk = ck.compress(&a, &b);
        // direct: per row pair scatter with pair hash
        for p in 0..3 {
            for h in 0..2 {
                let mut direct = vec![0.0; 8];
                for q in 0..5 {
                    for g in 0..4 {
                        direct[(ck.su.h(q) + ck.sv.h(g)) % 8] +=
                            ck.su.s(q) * ck.sv.s(g) * a.at2(p, q) * b.at2(h, g);
                    }
                }
                for k in 0..8 {
                    assert!((sk.get(&[p * 2 + h, k]) - direct[k]).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn cts_decompress_round_trip_shape() {
        let mut rng = Pcg64::new(5);
        let a = Tensor::randn(&[3, 3], &mut rng);
        let b = Tensor::randn(&[3, 3], &mut rng);
        let ck = CtsKron::new(&[3, 3], &[3, 3], 6, 8);
        let rec = ck.decompress(&ck.compress(&a, &b));
        assert_eq!(rec.dims(), &[9, 9]);
    }

    #[test]
    fn error_decreases_with_sketch_size() {
        // paper Fig 8: error grows with compression ratio; equivalently
        // shrinks as m grows. Use median of repeats for robustness.
        let mut rng = Pcg64::new(6);
        let a = Tensor::randn(&[10, 10], &mut rng);
        let b = Tensor::randn(&[10, 10], &mut rng);
        let truth = kron(&a, &b);
        let err_for = |m: usize| -> f64 {
            let errs: Vec<f64> = (0..5)
                .map(|rep| {
                    let mk = MtsKron::with_repeat(&[10, 10], &[10, 10], m, m, 42, rep);
                    rel_error(&truth, &mk.decompress(&mk.compress(&a, &b)))
                })
                .collect();
            crate::util::stats::median(&errs)
        };
        let e_small = err_for(8);
        let e_big = err_for(40);
        assert!(
            e_big < e_small,
            "error should shrink with sketch size: m=8→{e_small}, m=40→{e_big}"
        );
    }

    #[test]
    fn cached_fft_combine_matches_plain() {
        let mut rng = Pcg64::new(7);
        let a = Tensor::randn(&[6, 6], &mut rng);
        let b = Tensor::randn(&[6, 6], &mut rng);
        let mk = MtsKron::new(&[6, 6], &[6, 6], 5, 5, 3);
        let sa = mk.ska.sketch(&a);
        let sb = mk.skb.sketch(&b);
        let plain = mk.combine(&sa, &sb);
        let fa = mk.fft_of_sketch(&sa);
        let cached = mk.combine_with_cached(&fa, &sb);
        assert!(rel_error(&plain, &cached) < 1e-10);
    }

    #[test]
    fn combine_batch_matches_individual_combines() {
        // batch with a repeated operand: one A against many Bs must
        // reuse A's spectrum and still match job-by-job combines
        let mut rng = Pcg64::new(21);
        let mk = MtsKron::new(&[6, 6], &[6, 6], 5, 8, 9);
        let a = Tensor::randn(&[6, 6], &mut rng);
        let sa = mk.ska.sketch(&a);
        let sbs: Vec<Tensor> = (0..4)
            .map(|_| mk.skb.sketch(&Tensor::randn(&[6, 6], &mut rng)))
            .collect();
        let pairs: Vec<(&Tensor, &Tensor)> = sbs.iter().map(|sb| (&sa, sb)).collect();
        let batch = mk.combine_batch(&pairs);
        assert_eq!(batch.len(), 4);
        for (got, sb) in batch.iter().zip(sbs.iter()) {
            let want = mk.combine(&sa, sb);
            assert!(rel_error(&want, got) < 1e-10);
        }
    }

    #[test]
    fn kron_n_matches_pairwise_for_two_factors() {
        let mut rng = Pcg64::new(8);
        let a = Tensor::randn(&[5, 4], &mut rng);
        let b = Tensor::randn(&[3, 6], &mut rng);
        let n = MtsKronN::new(&[[5, 4], [3, 6]], 7, 7, 123);
        let pn = n.compress(&[&a, &b]);
        // direct scatter with the derived hashes
        let mut direct = Tensor::zeros(&[7, 7]);
        for p in 0..5 {
            for q in 0..4 {
                for h in 0..3 {
                    for g in 0..6 {
                        let r = (n.sketchers[0].mode(0).h(p) + n.sketchers[1].mode(0).h(h)) % 7;
                        let c = (n.sketchers[0].mode(1).h(q) + n.sketchers[1].mode(1).h(g)) % 7;
                        let s = n.sketchers[0].mode(0).s(p)
                            * n.sketchers[0].mode(1).s(q)
                            * n.sketchers[1].mode(0).s(h)
                            * n.sketchers[1].mode(1).s(g);
                        let v = direct.get(&[r, c]) + s * a.at2(p, q) * b.at2(h, g);
                        direct.set(&[r, c], v);
                    }
                }
            }
        }
        assert!(rel_error(&direct, &pn) < 1e-9);
    }

    #[test]
    fn kron_n_three_factor_unbiased() {
        let mut rng = Pcg64::new(9);
        let a = Tensor::randn(&[3, 3], &mut rng);
        let b = Tensor::randn(&[3, 3], &mut rng);
        let c = Tensor::randn(&[3, 3], &mut rng);
        let truth = a.at2(1, 2) * b.at2(0, 1) * c.at2(2, 0);
        let reps = 3000;
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let n = MtsKronN::with_repeat(&[[3, 3], [3, 3], [3, 3]], 5, 5, 77, rep);
                let p = n.compress(&[&a, &b, &c]);
                n.estimate(&p, &[1, 0, 2], &[2, 1, 0])
            })
            .collect();
        let m = mean(&est);
        let spread = (crate::util::stats::variance(&est) / reps as f64).sqrt();
        assert!((m - truth).abs() < 5.0 * spread.max(0.05), "{m} vs {truth}");
    }

    #[test]
    fn compression_ratios_match_paper_definitions() {
        let mk = MtsKron::new(&[10, 10], &[10, 10], 20, 20, 0);
        // ab·de/(m1 m2) = 100·100/400 = 25
        assert!((mk.compression_ratio() - 25.0).abs() < 1e-12);
        let ck = CtsKron::new(&[10, 10], &[10, 10], 40, 0);
        // de/c = 100/40 = 2.5
        assert!((ck.compression_ratio() - 2.5).abs() < 1e-12);
    }
}
