//! Sketching CP-form tensors (§3.1 REMARKS): CP is the diagonal-core
//! special case of Tucker, so both sketchers delegate to the Tucker
//! machinery but exploit the r-sparse core — the summation over the core
//! touches r terms instead of r^N, giving the Table 4/5 CP rows
//! (and the O(r) improvement in the overcomplete regime r > n).

use super::tucker::{CtsTucker, MtsTucker};
use crate::decomp::CpTensor;
use crate::fft::{self, Complex};
use crate::tensor::Tensor;

/// CTS of a CP-form tensor: `CTS(T) = Σ_{i=1}^r λ_i · CS(U_i) * CS(V_i) * …`
#[derive(Clone, Debug)]
pub struct CtsCp {
    inner: CtsTucker,
}

impl CtsCp {
    pub fn new(dims: &[usize], c: usize, seed: u64) -> Self {
        Self { inner: CtsTucker::new(dims, c, seed) }
    }

    pub fn with_repeat(dims: &[usize], c: usize, seed: u64, repeat: usize) -> Self {
        Self { inner: CtsTucker::with_repeat(dims, c, seed, repeat) }
    }

    /// Sketch from the CP form: r convolution terms (not r³), run on
    /// half spectra (one RFFT per factor column, one IRFFT total).
    pub fn sketch(&self, t: &CpTensor) -> Vec<f64> {
        assert_eq!(t.dims(), self.inner.dims, "CP dims mismatch");
        let c = self.inner.c;
        let hc = c / 2 + 1;
        let n_modes = self.inner.dims.len();
        let mut acc = vec![Complex::ZERO; hc];
        for (i, &w) in t.weights.iter().enumerate() {
            // ∏_k FFT(CS(U_k[:, i])) accumulated per frequency
            let mut term: Vec<Complex> = vec![Complex::new(w, 0.0); hc];
            for k in 0..n_modes {
                let mode = &self.inner.modes[k];
                let mut cs = vec![0.0; c];
                for row in 0..self.inner.dims[k] {
                    cs[mode.h(row)] += mode.s(row) * t.factors[k].at2(row, i);
                }
                let f = fft::rfft(&cs);
                for (t_, x) in term.iter_mut().zip(f.iter()) {
                    *t_ = *t_ * *x;
                }
            }
            for (a, t_) in acc.iter_mut().zip(term.iter()) {
                *a += *t_;
            }
        }
        fft::irfft(&acc, c)
    }

    pub fn estimate(&self, sk: &[f64], idx: &[usize]) -> f64 {
        self.inner.estimate(sk, idx)
    }

    pub fn decompress(&self, sk: &[f64]) -> Tensor {
        self.inner.decompress(sk)
    }
}

/// MTS of a CP-form tensor: identical to [`MtsTucker`] except the core
/// sketch iterates the r diagonal entries only.
#[derive(Clone, Debug)]
pub struct MtsCp {
    inner: MtsTucker,
}

impl MtsCp {
    pub fn new(dims: &[usize], rank: usize, m1: usize, m2: usize, seed: u64) -> Self {
        let ranks = vec![rank; dims.len()];
        Self { inner: MtsTucker::new(dims, &ranks, m1, m2, seed) }
    }

    pub fn with_repeat(
        dims: &[usize],
        rank: usize,
        m1: usize,
        m2: usize,
        seed: u64,
        repeat: usize,
    ) -> Self {
        let ranks = vec![rank; dims.len()];
        Self { inner: MtsTucker::with_repeat(dims, &ranks, m1, m2, seed, repeat) }
    }

    pub fn sketch(&self, t: &CpTensor) -> Vec<f64> {
        assert_eq!(t.dims(), self.inner.dims, "CP dims mismatch");
        assert_eq!(t.rank(), self.inner.ranks[0], "CP rank mismatch");
        // 1. factor Kronecker sketch in frequency domain (as Tucker),
        //    accumulated on real-input half spectra
        let mut freq: Option<Vec<Complex>> = None;
        for (k, f) in t.factors.iter().enumerate() {
            let sk = self.inner.factor_sk[k].sketch(f);
            let fa = fft::rfft2(sk.data(), self.inner.m1, self.inner.m2);
            freq = Some(match freq {
                None => fa,
                Some(mut acc) => {
                    for (a, b) in acc.iter_mut().zip(fa.iter()) {
                        *a = *a * *b;
                    }
                    acc
                }
            });
        }
        let kron_sketch = fft::irfft2(&freq.unwrap(), self.inner.m1, self.inner.m2);

        // 2. diagonal core CS: r terms
        let mut csg = vec![0.0; self.inner.m2];
        let n_modes = self.inner.dims.len();
        for (i, &w) in t.weights.iter().enumerate() {
            let mut bucket = 0usize;
            let mut sign = 1.0;
            for k in 0..n_modes {
                let mode = self.inner.factor_sk[k].mode(1);
                bucket += mode.h(i);
                sign *= mode.s(i);
            }
            csg[bucket % self.inner.m2] += sign * w;
        }

        // 3. collapse m2
        let mut out = vec![0.0; self.inner.m1];
        for (t1, o) in out.iter_mut().enumerate() {
            let row = &kron_sketch[t1 * self.inner.m2..(t1 + 1) * self.inner.m2];
            *o = row.iter().zip(csg.iter()).map(|(x, g)| x * g).sum();
        }
        out
    }

    pub fn estimate(&self, sk: &[f64], idx: &[usize]) -> f64 {
        self.inner.estimate(sk, idx)
    }

    pub fn decompress(&self, sk: &[f64]) -> Tensor {
        self.inner.decompress(sk)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::util::stats::{mean, variance};

    fn small_cp(seed: u64, dims: &[usize], r: usize) -> CpTensor {
        let mut rng = Pcg64::new(seed);
        CpTensor::random(dims, r, &mut rng)
    }

    #[test]
    fn cts_cp_matches_tucker_path_on_diagonal_core() {
        let cp = small_cp(1, &[5, 5, 5], 3);
        let cts_cp = CtsCp::new(&[5, 5, 5], 16, 42);
        let via_cp = cts_cp.sketch(&cp);
        let via_tucker = cts_cp.inner.sketch(&cp.to_tucker());
        for (a, b) in via_cp.iter().zip(via_tucker.iter()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn mts_cp_matches_tucker_path_on_diagonal_core() {
        let cp = small_cp(2, &[5, 5, 5], 3);
        let mts_cp = MtsCp::new(&[5, 5, 5], 3, 8, 8, 7);
        let via_cp = mts_cp.sketch(&cp);
        let via_tucker = mts_cp.inner.sketch(&cp.to_tucker());
        for (a, b) in via_cp.iter().zip(via_tucker.iter()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn cp_estimates_unbiased() {
        let cp = small_cp(3, &[6, 6, 6], 2);
        let dense = cp.reconstruct();
        let target = [2usize, 5, 1];
        let truth = dense.get(&target);
        let reps = 2500;
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let s = MtsCp::with_repeat(&[6, 6, 6], 2, 8, 8, 31, rep);
                s.estimate(&s.sketch(&cp), &target)
            })
            .collect();
        let m = mean(&est);
        let spread = (variance(&est) / reps as f64).sqrt();
        assert!((m - truth).abs() < 5.0 * spread.max(0.02), "{m} vs {truth}");
    }

    #[test]
    fn overcomplete_cp_sketches_fine() {
        // r > n regime the paper highlights (O(r) improvement)
        let cp = small_cp(4, &[4, 4, 4], 10);
        let cts = CtsCp::new(&[4, 4, 4], 32, 9);
        let mts = MtsCp::new(&[4, 4, 4], 10, 16, 16, 9);
        assert_eq!(cts.sketch(&cp).len(), 32);
        assert_eq!(mts.sketch(&cp).len(), 16);
    }
}
