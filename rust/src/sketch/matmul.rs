//! Compressed matrix multiplication in MTS space (§2.3's motivating
//! tensor contraction; generalizes Pagh 2012 from a 1-D to a 2-D
//! sketch).
//!
//! For `C = A·B` with `A ∈ ℝ^{n×k}`, `B ∈ ℝ^{k×p}`:
//! write `C = Σ_l A[:,l] ⊗ B[l,:]`. Hash C's rows with `(h_r, s_r)` and
//! columns with `(h_c, s_c)`, and the inner axis with `(h_i, s_i)`;
//! then
//!
//! `MTS(C) ≈ Σ_t  Ã[:,t] ⊗ B̃[t,:]`
//!
//! where `Ã = MTS(A)` (rows → m1, inner → m_i) and `B̃ = MTS(B)`
//! (inner → m_i, cols → m2) share the inner hash. Expanding shows the
//! estimator `Ĉ[i,j] = s_r(i)s_c(j)·P[h_r(i), h_c(j)]` is unbiased:
//! inner-axis collisions (l ≠ l′ with h_i(l) = h_i(l′)) carry the sign
//! product `s_i(l)s_i(l′)` with zero mean. Cost: O(nk + kp) to sketch,
//! O(m1·m_i·m2) to combine, O(m1·m2) memory — never forming `C`.

use super::mts::MtsSketcher;
use crate::tensor::Tensor;

/// Sketched matrix product `A·B` computed entirely in sketch space.
#[derive(Clone, Debug)]
pub struct MtsMatmul {
    pub n: usize,
    pub k: usize,
    pub p: usize,
    pub m_rows: usize,
    pub m_inner: usize,
    pub m_cols: usize,
    /// A: rows → m_rows, inner → m_inner
    ska: MtsSketcher,
    /// B: inner → m_inner (same hashes as ska mode 1), cols → m_cols
    skb: MtsSketcher,
}

impl MtsMatmul {
    pub fn new(
        n: usize,
        k: usize,
        p: usize,
        m_rows: usize,
        m_inner: usize,
        m_cols: usize,
        seed: u64,
    ) -> Self {
        Self::with_repeat(n, k, p, m_rows, m_inner, m_cols, seed, 0)
    }

    #[allow(clippy::too_many_arguments)]
    pub fn with_repeat(
        n: usize,
        k: usize,
        p: usize,
        m_rows: usize,
        m_inner: usize,
        m_cols: usize,
        seed: u64,
        repeat: usize,
    ) -> Self {
        // the inner hash must be SHARED: build A's sketcher, then build
        // B's from a seed derived so its mode-0 (inner) hash equals A's
        // mode-1 hash. MtsSketcher derives per-mode seeds from
        // (seed, repeat, mode); we construct B with swapped dims and
        // reuse A's inner ModeHash via the explicit constructor below.
        let ska = MtsSketcher::with_repeat(&[n, k], &[m_rows, m_inner], seed, 2 * repeat);
        let skb = MtsSketcher::with_modes(
            &[k, p],
            &[m_inner, m_cols],
            vec![
                ska.mode(1).clone(),
                crate::hash::ModeHash::new(
                    p,
                    m_cols,
                    crate::hash::HashSeeds::new(seed ^ 0x00C0_FFEE).seed_for(repeat, 5),
                ),
            ],
        );
        Self { n, k, p, m_rows, m_inner, m_cols, ska, skb }
    }

    /// Sketch both factors and combine: `P = Ã · B̃` (m_rows × m_cols).
    pub fn compress(&self, a: &Tensor, b: &Tensor) -> Tensor {
        assert_eq!(a.dims(), &[self.n, self.k], "A shape");
        assert_eq!(b.dims(), &[self.k, self.p], "B shape");
        let sa = self.ska.sketch(a); // m_rows × m_inner
        let sb = self.skb.sketch(b); // m_inner × m_cols
        sa.matmul(&sb)
    }

    /// Unbiased estimate of `C[i, j]`.
    #[inline]
    pub fn estimate(&self, p_sk: &Tensor, i: usize, j: usize) -> f64 {
        let r = self.ska.mode(0);
        let c = self.skb.mode(1);
        r.s(i) * c.s(j) * p_sk.get(&[r.h(i), c.h(j)])
    }

    /// Full reconstruction of the product.
    pub fn decompress(&self, p_sk: &Tensor) -> Tensor {
        let mut out = Tensor::zeros(&[self.n, self.p]);
        for i in 0..self.n {
            for j in 0..self.p {
                out.set(&[i, j], self.estimate(p_sk, i, j));
            }
        }
        out
    }

    /// Compression ratio n·p / (m_rows·m_cols).
    pub fn compression_ratio(&self) -> f64 {
        (self.n * self.p) as f64 / (self.m_rows * self.m_cols) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::util::stats::{mean, variance};

    #[test]
    fn exact_when_hashes_injective() {
        // choose sketch dims >> dims and retry seeds until all three
        // hashes are injective — then the product is recovered exactly
        let (n, k, p) = (5usize, 4usize, 6usize);
        let mut rng = Pcg64::new(1);
        let a = Tensor::randn(&[n, k], &mut rng);
        let b = Tensor::randn(&[k, p], &mut rng);
        let truth = a.matmul(&b);
        'seeds: for seed in 0..100 {
            let mm = MtsMatmul::new(n, k, p, 64, 64, 64, seed);
            for (mh, dim) in [
                (mm.ska.mode(0), n),
                (mm.ska.mode(1), k),
                (mm.skb.mode(1), p),
            ] {
                let mut seen = std::collections::HashSet::new();
                for i in 0..dim {
                    if !seen.insert(mh.h(i)) {
                        continue 'seeds;
                    }
                }
            }
            let rec = mm.decompress(&mm.compress(&a, &b));
            assert!(crate::tensor::rel_error(&truth, &rec) < 1e-9);
            return;
        }
        panic!("no injective seed found");
    }

    #[test]
    fn estimator_unbiased() {
        let (n, k, p) = (6usize, 5usize, 6usize);
        let mut rng = Pcg64::new(2);
        let a = Tensor::randn(&[n, k], &mut rng);
        let b = Tensor::randn(&[k, p], &mut rng);
        let truth = a.matmul(&b).at2(2, 4);
        let reps = 4000;
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let mm = MtsMatmul::with_repeat(n, k, p, 4, 4, 4, 33, rep);
                mm.estimate(&mm.compress(&a, &b), 2, 4)
            })
            .collect();
        let m = mean(&est);
        let spread = (variance(&est) / reps as f64).sqrt();
        assert!((m - truth).abs() < 5.0 * spread.max(0.02), "{m} vs {truth}");
    }

    #[test]
    fn inner_hashes_are_shared() {
        let mm = MtsMatmul::new(8, 10, 6, 4, 4, 4, 7);
        for l in 0..10 {
            assert_eq!(mm.ska.mode(1).h(l), mm.skb.mode(0).h(l));
            assert_eq!(mm.ska.mode(1).s(l), mm.skb.mode(0).s(l));
        }
    }

    #[test]
    fn error_decreases_with_sketch_size() {
        let (n, k, p) = (10usize, 8usize, 10usize);
        let mut rng = Pcg64::new(3);
        let a = Tensor::randn(&[n, k], &mut rng);
        let b = Tensor::randn(&[k, p], &mut rng);
        let truth = a.matmul(&b);
        let err = |m: usize| {
            let errs: Vec<f64> = (0..5)
                .map(|rep| {
                    let mm = MtsMatmul::with_repeat(n, k, p, m, m, m, 9, rep);
                    crate::tensor::rel_error(&truth, &mm.decompress(&mm.compress(&a, &b)))
                })
                .collect();
            crate::util::stats::median(&errs)
        };
        assert!(err(32) < err(4), "32: {}, 4: {}", err(32), err(4));
    }

    #[test]
    fn covariance_special_case_consistent() {
        // C = A·Aᵀ through MtsMatmul should track the dedicated
        // covariance route in error magnitude
        let mut rng = Pcg64::new(4);
        let a = Tensor::randn(&[8, 6], &mut rng);
        let truth = a.matmul(&a.transpose());
        let mm = MtsMatmul::new(8, 6, 8, 16, 16, 16, 11);
        let rec = mm.decompress(&mm.compress(&a, &a.transpose()));
        let err = crate::tensor::rel_error(&truth, &rec);
        assert!(err < 1.5, "err {err}");
    }
}
