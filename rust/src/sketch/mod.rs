//! The paper's contribution: sketching algorithms that retain efficient
//! tensor operations.
//!
//! | Module | Paper section | What it implements |
//! |---|---|---|
//! | [`cs`] | §2.2, Alg. 1 | Count sketch of vectors + Pagh's outer-product sketch |
//! | [`cts`] | §2.2, Alg. 2 | Count-based tensor sketch (per-fibre CS — the baseline) |
//! | [`mts`] | §2.3, Alg. 3 | Multi-dimensional tensor sketch (MTS/HCS) — the contribution |
//! | [`kron`] | §2.4, Alg. 4, Lemma B.1 | Sketched Kronecker products, CTS vs MTS |
//! | [`tucker`] | §3.1, Eq. 7/8, Thm 3.1/3.2 | Sketching Tucker-form tensors |
//! | [`cp`] | §3.1 REMARKS | Sketching CP-form tensors |
//! | [`tt`] | §3.2, Alg. 5 | Sketching tensor-train tensors |
//! | [`covariance`] | §4.2 | Covariance estimation via sketched Kronecker |
//! | [`estimate`] | §2.2 | Median-of-d robust estimation |
//!
//! Everything is seeded and exactly reproducible; every sketcher exposes
//! `sketch` / `decompress` (full tensor) and `estimate` (single entry)
//! so the benches can measure both throughput and pointwise recovery.

pub mod covariance;
pub mod cp;
pub mod cs;
pub mod cts;
pub mod estimate;
pub mod inner;
pub mod kernel;
pub mod kron;
pub mod matmul;
pub mod mts;
pub mod stream;
pub mod tt;
pub mod tucker;

pub use cs::CsSketcher;
pub use cts::CtsSketcher;
pub use mts::MtsSketcher;

/// Alias: the paper's later revision renamed MTS to Higher-order Count
/// Sketch (HCS). Same algorithm.
pub type HigherOrderCountSketch = mts::MtsSketcher;
