//! Count sketch of vectors (Charikar et al. 2002, paper Algorithm 1) and
//! Pagh's FFT outer-product sketch (paper Eq. 2):
//! `CS(u ⊗ v) = CS(u) * CS(v)`.

use crate::fft::circular_convolve_real;
use crate::hash::ModeHash;

/// Count sketch of length-`n` vectors into `c` buckets.
///
/// Holds the materialized `(h, s)` tables so the hot loop is two array
/// lookups per element.
#[derive(Clone, Debug)]
pub struct CsSketcher {
    pub n: usize,
    pub c: usize,
    buckets: Vec<u32>,
    signs: Vec<f64>,
}

impl CsSketcher {
    pub fn new(n: usize, c: usize, seed: u64) -> Self {
        let mh = ModeHash::new(n, c, seed);
        Self { n, c, buckets: mh.bucket_table(), signs: mh.sign_table() }
    }

    #[inline]
    pub fn h(&self, i: usize) -> usize {
        self.buckets[i] as usize
    }

    #[inline]
    pub fn s(&self, i: usize) -> f64 {
        self.signs[i]
    }

    /// `CS(x)`: y[h(i)] += s(i)·x[i].
    pub fn sketch(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n, "input length {} != n {}", x.len(), self.n);
        let mut y = vec![0.0; self.c];
        for (i, &v) in x.iter().enumerate() {
            y[self.buckets[i] as usize] += self.signs[i] * v;
        }
        y
    }

    /// `CS(x)` for a whole batch of inputs. The bucket/sign tables are
    /// streamed once per tile of inputs instead of once per input, so
    /// table traffic amortizes over the batch. This is the f64 library
    /// form of the tiling; the coordinator's `PureRustBackend` applies
    /// the same scheme to its f32 manifest-driven kernels.
    pub fn sketch_batch(&self, xs: &[&[f64]]) -> Vec<Vec<f64>> {
        for (r, x) in xs.iter().enumerate() {
            assert_eq!(x.len(), self.n, "batch row {r}: input length {} != n {}", x.len(), self.n);
        }
        let mut outs = vec![vec![0.0f64; self.c]; xs.len()];
        // tile so the tile's outputs stay cache-resident while the
        // tables stream through
        const TILE: usize = 8;
        let mut start = 0;
        while start < xs.len() {
            let end = (start + TILE).min(xs.len());
            for i in 0..self.n {
                let b = self.buckets[i] as usize;
                let s = self.signs[i];
                for (x, out) in xs[start..end].iter().zip(outs[start..end].iter_mut()) {
                    out[b] += s * x[i];
                }
            }
            start = end;
        }
        outs
    }

    /// Point estimate `x̂[i] = s(i)·y[h(i)]` (unbiased, Thm B.2).
    ///
    /// The sketch length is validated with a real assert: a short slice
    /// would silently read the wrong bucket in release builds.
    #[inline]
    pub fn estimate(&self, y: &[f64], i: usize) -> f64 {
        assert_eq!(y.len(), self.c, "sketch length {} != c {}", y.len(), self.c);
        self.signs[i] * y[self.buckets[i] as usize]
    }

    /// Full decompression (Algorithm 1, CS-Decompress).
    pub fn decompress(&self, y: &[f64]) -> Vec<f64> {
        (0..self.n).map(|i| self.estimate(y, i)).collect()
    }
}

/// Pagh's outer-product sketch: `CS(u ⊗ v) = CS_u(u) * CS_v(v)` where `*`
/// is circular convolution, computed via FFT in O(n + c log c).
///
/// The combined sketch estimates `(u⊗v)[i,j]` with hash
/// `h(i,j) = (h_u(i) + h_v(j)) mod c` and sign `s_u(i)·s_v(j)`.
pub fn sketch_outer_product(su: &CsSketcher, sv: &CsSketcher, u: &[f64], v: &[f64]) -> Vec<f64> {
    assert_eq!(su.c, sv.c, "outer-product sketches must share c");
    circular_convolve_real(&su.sketch(u), &sv.sketch(v))
}

/// Estimate `(u⊗v)[i,j]` from a combined outer-product sketch.
#[inline]
pub fn estimate_outer_entry(
    su: &CsSketcher,
    sv: &CsSketcher,
    sketch: &[f64],
    i: usize,
    j: usize,
) -> f64 {
    let k = (su.h(i) + sv.h(j)) % su.c;
    su.s(i) * sv.s(j) * sketch[k]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::util::stats::{mean, variance};

    #[test]
    fn sketch_preserves_mass_signs() {
        // a single nonzero is recovered exactly
        let cs = CsSketcher::new(100, 10, 1);
        let mut x = vec![0.0; 100];
        x[37] = 3.5;
        let y = cs.sketch(&x);
        assert!((cs.estimate(&y, 37) - 3.5).abs() < 1e-12);
        // total sketch energy equals input energy for a 1-sparse input
        let e: f64 = y.iter().map(|v| v * v).sum();
        assert!((e - 3.5 * 3.5).abs() < 1e-12);
    }

    #[test]
    fn estimator_is_unbiased() {
        // E[x̂_i] = x_i across independent sketches
        let n = 64;
        let mut rng = Pcg64::new(2);
        let x = rng.normal_vec(n);
        let i = 17;
        let reps = 4000;
        let mut est = Vec::with_capacity(reps);
        for rep in 0..reps {
            let cs = CsSketcher::new(n, 8, 1000 + rep as u64);
            let y = cs.sketch(&x);
            est.push(cs.estimate(&y, i));
        }
        let m = mean(&est);
        // stderr ≈ sqrt(‖x‖²/c / reps)
        let norm_sq: f64 = x.iter().map(|v| v * v).sum();
        let stderr = (norm_sq / 8.0 / reps as f64).sqrt();
        assert!(
            (m - x[i]).abs() < 4.0 * stderr,
            "mean {m} vs true {} (stderr {stderr})",
            x[i]
        );
    }

    #[test]
    fn variance_bounded_by_theorem_b2() {
        // Var[x̂_i] ≤ ‖x‖²/c
        let n = 64;
        let c = 16;
        let mut rng = Pcg64::new(3);
        let x = rng.normal_vec(n);
        let norm_sq: f64 = x.iter().map(|v| v * v).sum();
        let i = 5;
        let reps = 4000;
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let cs = CsSketcher::new(n, c, 5000 + rep as u64);
                cs.estimate(&cs.sketch(&x), i)
            })
            .collect();
        let v = variance(&est);
        let bound = norm_sq / c as f64;
        // allow sampling slack
        assert!(v < bound * 1.3, "empirical var {v} exceeds bound {bound}");
    }

    #[test]
    fn decompress_shape_and_identity_regime() {
        // with c >= n and injective-ish hashing, most entries recover well;
        // at minimum the decompressed vector has the right length
        let cs = CsSketcher::new(16, 64, 4);
        let mut rng = Pcg64::new(4);
        let x = rng.normal_vec(16);
        let xhat = cs.decompress(&cs.sketch(&x));
        assert_eq!(xhat.len(), 16);
    }

    #[test]
    fn outer_product_sketch_matches_direct_sketch() {
        // Pagh Eq. 2: sketching the outer product directly with the pair
        // hash equals convolving the two sketches.
        let (nu, nv, c) = (12, 9, 16);
        let su = CsSketcher::new(nu, c, 10);
        let sv = CsSketcher::new(nv, c, 11);
        let mut rng = Pcg64::new(5);
        let u = rng.normal_vec(nu);
        let v = rng.normal_vec(nv);
        let combined = sketch_outer_product(&su, &sv, &u, &v);
        // direct: scatter u_i v_j at (h_u(i)+h_v(j)) mod c with sign product
        let mut direct = vec![0.0; c];
        for i in 0..nu {
            for j in 0..nv {
                direct[(su.h(i) + sv.h(j)) % c] += su.s(i) * sv.s(j) * u[i] * v[j];
            }
        }
        for k in 0..c {
            assert!((combined[k] - direct[k]).abs() < 1e-9, "bucket {k}");
        }
    }

    #[test]
    fn outer_entry_estimates_unbiased() {
        let (nu, nv, c) = (10, 10, 12);
        let mut rng = Pcg64::new(6);
        let u = rng.normal_vec(nu);
        let v = rng.normal_vec(nv);
        let truth = u[3] * v[7];
        let reps = 3000;
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let su = CsSketcher::new(nu, c, 100 + 2 * rep as u64);
                let sv = CsSketcher::new(nv, c, 101 + 2 * rep as u64);
                let sk = sketch_outer_product(&su, &sv, &u, &v);
                estimate_outer_entry(&su, &sv, &sk, 3, 7)
            })
            .collect();
        let m = mean(&est);
        let spread = (variance(&est) / reps as f64).sqrt();
        assert!((m - truth).abs() < 5.0 * spread.max(0.01), "{m} vs {truth}");
    }

    #[test]
    #[should_panic(expected = "input length")]
    fn wrong_length_panics() {
        CsSketcher::new(8, 4, 0).sketch(&[1.0; 9]);
    }

    #[test]
    #[should_panic(expected = "sketch length")]
    fn estimate_rejects_short_sketch_in_release_too() {
        let cs = CsSketcher::new(8, 4, 0);
        let y = vec![0.0; 3]; // one short of c = 4
        cs.estimate(&y, 0);
    }

    #[test]
    fn sketch_batch_matches_single_sketches() {
        let cs = CsSketcher::new(50, 7, 11);
        let mut rng = Pcg64::new(8);
        // more rows than one tile to exercise the tiling
        let rows: Vec<Vec<f64>> = (0..19).map(|_| rng.normal_vec(50)).collect();
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let batch = cs.sketch_batch(&refs);
        assert_eq!(batch.len(), 19);
        for (row, got) in rows.iter().zip(batch.iter()) {
            // identical accumulation order → exact equality
            assert_eq!(got, &cs.sketch(row));
        }
    }

    #[test]
    fn sketch_batch_empty_is_empty() {
        let cs = CsSketcher::new(4, 2, 0);
        assert!(cs.sketch_batch(&[]).is_empty());
    }
}
