//! Sketching Tucker-form tensors (§3.1).
//!
//! - [`CtsTucker`] (Eq. 7, Thm 3.1): `CTS(T) = Σ_{abc} G_abc ·
//!   CS(U_a) * CS(V_b) * CS(W_c)` — a length-`c` count sketch of
//!   `vec(T)` under the composite hash `h(i,j,k) = Σ_k h_k(i_k) mod c`.
//!   Computed in the frequency domain: one FFT per factor column, the
//!   r³ summation as per-frequency multilinear contractions, one IFFT.
//! - [`MtsTucker`] (Eq. 8, Thm 3.2): rewrite `vec(T) = (U⊗V⊗W)·vec(G)`
//!   and run Pagh's compressed matrix multiplication *in MTS space*:
//!   `MTS(U⊗V⊗W)` is the FFT2-combine of the factor sketches
//!   (Lemma B.1 extended to N factors), `vec(G)` is count-sketched with
//!   the matching composite column hash, and the product collapses the
//!   m₂ axis. O(nr + r³ + m₁m₂log(m₁m₂)) vs CTS's O(r³(n + c log c)).
//!
//! Both sketchers work for any order N ≥ 2 (the paper presents N = 3).

use super::mts::MtsSketcher;
use crate::decomp::TuckerTensor;
use crate::fft::{self, Complex};
use crate::hash::{HashSeeds, ModeHash};
use crate::tensor::Tensor;

// ---------------------------------------------------------------------
// CTS variant (Eq. 7)
// ---------------------------------------------------------------------

/// Count-sketch of a Tucker-form tensor into a length-`c` vector.
#[derive(Clone, Debug)]
pub struct CtsTucker {
    pub dims: Vec<usize>,
    pub c: usize,
    /// per-mode (h, s) over the ambient index n_k
    pub(crate) modes: Vec<ModeHash>,
}

impl CtsTucker {
    pub fn new(dims: &[usize], c: usize, seed: u64) -> Self {
        Self::with_repeat(dims, c, seed, 0)
    }

    pub fn with_repeat(dims: &[usize], c: usize, seed: u64, repeat: usize) -> Self {
        let seeds = HashSeeds::new(seed);
        let modes = dims
            .iter()
            .enumerate()
            .map(|(k, &n)| ModeHash::new(n, c, seeds.seed_for(repeat, k)))
            .collect();
        Self { dims: dims.to_vec(), c, modes }
    }

    /// Sketch from the decomposed form — never reconstructs the dense
    /// tensor (that is the whole point).
    pub fn sketch(&self, t: &TuckerTensor) -> Vec<f64> {
        assert_eq!(t.dims(), self.dims, "Tucker dims mismatch");
        let n_modes = self.dims.len();
        let ranks = t.ranks();
        let hc = self.c / 2 + 1;
        // half spectrum (RFFT) of CS of each factor column: per mode an
        // r_k × (c/2 + 1) complex table — real inputs, so the redundant
        // half of every spectrum is never computed or multiplied
        let spectra: Vec<Vec<Vec<Complex>>> = (0..n_modes)
            .map(|k| {
                let f = &t.factors[k];
                (0..ranks[k])
                    .map(|col| {
                        let mut cs = vec![0.0; self.c];
                        for i in 0..self.dims[k] {
                            cs[self.modes[k].h(i)] += self.modes[k].s(i) * f.at2(i, col);
                        }
                        fft::rfft(&cs)
                    })
                    .collect()
            })
            .collect();
        // frequency-domain accumulation: for each frequency f,
        // acc[f] = Σ_{a,b,…} G[a,b,…] ∏_k spectra[k][idx_k][f]
        // computed as a sequential contraction of G with the per-mode
        // spectral vectors (O(c·Σ r^k) instead of O(c·r^N·N)).
        let mut acc = vec![Complex::ZERO; hc];
        let core = &t.core;
        for (f, a) in acc.iter_mut().enumerate() {
            // contract core with vectors v_k[j] = spectra[k][j][f]
            let mut cur: Vec<Complex> =
                core.data().iter().map(|&x| Complex::new(x, 0.0)).collect();
            let mut cur_len = cur.len();
            for k in (0..n_modes).rev() {
                // contract the last mode of cur (length ranks[k])
                let rk = ranks[k];
                let rows = cur_len / rk;
                let mut next = vec![Complex::ZERO; rows];
                for (row, n_) in next.iter_mut().enumerate() {
                    let mut s = Complex::ZERO;
                    for j in 0..rk {
                        s += cur[row * rk + j] * spectra[k][j][f];
                    }
                    *n_ = s;
                }
                cur = next;
                cur_len = rows;
            }
            *a = cur[0];
        }
        fft::irfft(&acc, self.c)
    }

    /// Point estimate `T̂[idx]`.
    #[inline]
    pub fn estimate(&self, sk: &[f64], idx: &[usize]) -> f64 {
        let mut bucket = 0usize;
        let mut sign = 1.0;
        for (k, &i) in idx.iter().enumerate() {
            bucket += self.modes[k].h(i);
            sign *= self.modes[k].s(i);
        }
        sign * sk[bucket % self.c]
    }

    /// Full dense reconstruction.
    pub fn decompress(&self, sk: &[f64]) -> Tensor {
        let mut out = Tensor::zeros(&self.dims);
        let n = self.dims.len();
        let mut idx = vec![0usize; n];
        for v in out.data_mut() {
            *v = self.estimate(sk, &idx);
            for k in (0..n).rev() {
                idx[k] += 1;
                if idx[k] < self.dims[k] {
                    break;
                }
                idx[k] = 0;
            }
        }
        out
    }

    /// Sketch memory in floats (Table 4's O(cr + r³) counts the
    /// intermediates; the *sketch itself* is c).
    pub fn sketch_len(&self) -> usize {
        self.c
    }
}

// ---------------------------------------------------------------------
// MTS variant (Eq. 8)
// ---------------------------------------------------------------------

/// MTS of a Tucker-form tensor via compressed matrix multiplication in
/// sketch space. Final sketch: length-`m1` count sketch of `vec(T)`
/// under the composite row hash, produced through an `m1 × m2`
/// intermediate (the MTS of `U⊗V⊗…`).
#[derive(Clone, Debug)]
pub struct MtsTucker {
    pub dims: Vec<usize>,
    pub ranks: Vec<usize>,
    pub m1: usize,
    pub m2: usize,
    /// per-factor MTS (rows n_k → m1, cols r_k → m2)
    pub(crate) factor_sk: Vec<MtsSketcher>,
}

impl MtsTucker {
    pub fn new(dims: &[usize], ranks: &[usize], m1: usize, m2: usize, seed: u64) -> Self {
        Self::with_repeat(dims, ranks, m1, m2, seed, 0)
    }

    pub fn with_repeat(
        dims: &[usize],
        ranks: &[usize],
        m1: usize,
        m2: usize,
        seed: u64,
        repeat: usize,
    ) -> Self {
        assert_eq!(dims.len(), ranks.len());
        let factor_sk = dims
            .iter()
            .zip(ranks.iter())
            .enumerate()
            .map(|(k, (&n, &r))| {
                MtsSketcher::with_repeat(
                    &[n, r],
                    &[m1, m2],
                    seed ^ (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    repeat,
                )
            })
            .collect();
        Self { dims: dims.to_vec(), ranks: ranks.to_vec(), m1, m2, factor_sk }
    }

    /// Sketch from the decomposed form.
    pub fn sketch(&self, t: &TuckerTensor) -> Vec<f64> {
        assert_eq!(t.dims(), self.dims, "Tucker dims mismatch");
        assert_eq!(t.ranks(), self.ranks, "Tucker ranks mismatch");
        // 1. MTS of each factor, combined in the 2-D frequency domain:
        //    MTS(U ⊗ V ⊗ …) = IFFT2(∏ FFT2(MTS(U_k)))  [Lemma B.1, N-ary]
        //    — accumulated on real-input half spectra (m1 × (m2/2 + 1))
        let mut freq: Option<Vec<Complex>> = None;
        for (k, f) in t.factors.iter().enumerate() {
            let sk = self.factor_sk[k].sketch(f);
            let fa = fft::rfft2(sk.data(), self.m1, self.m2);
            freq = Some(match freq {
                None => fa,
                Some(mut acc) => {
                    for (a, b) in acc.iter_mut().zip(fa.iter()) {
                        *a = *a * *b;
                    }
                    acc
                }
            });
        }
        let kron_sketch = fft::irfft2(&freq.unwrap(), self.m1, self.m2); // m1×m2

        // 2. CS of vec(G) under the composite column hash
        let csg = self.sketch_core(&t.core);

        // 3. collapse the m2 axis: out[t1] = Σ_{t2} K[t1,t2]·csg[t2]
        let mut out = vec![0.0; self.m1];
        for t1 in 0..self.m1 {
            let row = &kron_sketch[t1 * self.m2..(t1 + 1) * self.m2];
            let mut acc = 0.0;
            for (x, g) in row.iter().zip(csg.iter()) {
                acc += x * g;
            }
            out[t1] = acc;
        }
        out
    }

    /// CS of `vec(G)` with composite column hash
    /// `h(a,b,…) = Σ_k h₂ₖ(a_k) mod m2`, sign `∏ s₂ₖ(a_k)`.
    /// Exposed for the CP special case (diagonal core).
    pub fn sketch_core(&self, core: &Tensor) -> Vec<f64> {
        assert_eq!(core.dims(), self.ranks.as_slice());
        let n = self.ranks.len();
        let mut out = vec![0.0; self.m2];
        let mut idx = vec![0usize; n];
        for &g in core.data() {
            if g != 0.0 {
                let mut bucket = 0usize;
                let mut sign = 1.0;
                for (k, &a) in idx.iter().enumerate() {
                    let mode = self.factor_sk[k].mode(1);
                    bucket += mode.h(a);
                    sign *= mode.s(a);
                }
                out[bucket % self.m2] += sign * g;
            }
            for k in (0..n).rev() {
                idx[k] += 1;
                if idx[k] < self.ranks[k] {
                    break;
                }
                idx[k] = 0;
            }
        }
        out
    }

    /// Point estimate: `T̂[idx] = ∏ s₁ₖ(i_k) · sk[Σ h₁ₖ(i_k) mod m1]`.
    #[inline]
    pub fn estimate(&self, sk: &[f64], idx: &[usize]) -> f64 {
        let mut bucket = 0usize;
        let mut sign = 1.0;
        for (k, &i) in idx.iter().enumerate() {
            let mode = self.factor_sk[k].mode(0);
            bucket += mode.h(i);
            sign *= mode.s(i);
        }
        sign * sk[bucket % self.m1]
    }

    pub fn decompress(&self, sk: &[f64]) -> Tensor {
        let mut out = Tensor::zeros(&self.dims);
        let n = self.dims.len();
        let mut idx = vec![0usize; n];
        for v in out.data_mut() {
            *v = self.estimate(sk, &idx);
            for k in (0..n).rev() {
                idx[k] += 1;
                if idx[k] < self.dims[k] {
                    break;
                }
                idx[k] = 0;
            }
        }
        out
    }

    pub fn sketch_len(&self) -> usize {
        self.m1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::util::stats::{mean, median};

    fn small_tucker(seed: u64) -> TuckerTensor {
        let mut rng = Pcg64::new(seed);
        TuckerTensor::random(&[6, 6, 6], &[2, 2, 2], &mut rng)
    }

    #[test]
    fn cts_sketch_equals_direct_composite_cs_of_dense() {
        // the factored computation must equal count-sketching the dense
        // tensor with the composite hash
        let t = small_tucker(1);
        let dense = t.reconstruct();
        let cts = CtsTucker::new(&[6, 6, 6], 16, 11);
        let sk = cts.sketch(&t);
        let mut direct = vec![0.0; 16];
        for i in 0..6 {
            for j in 0..6 {
                for k in 0..6 {
                    let b = (cts.modes[0].h(i) + cts.modes[1].h(j) + cts.modes[2].h(k)) % 16;
                    let s = cts.modes[0].s(i) * cts.modes[1].s(j) * cts.modes[2].s(k);
                    direct[b] += s * dense.get(&[i, j, k]);
                }
            }
        }
        for (a, b) in sk.iter().zip(direct.iter()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn cts_estimate_unbiased() {
        let t = small_tucker(2);
        let dense = t.reconstruct();
        let target = [1usize, 4, 2];
        let truth = dense.get(&target);
        let reps = 2500;
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let cts = CtsTucker::with_repeat(&[6, 6, 6], 24, 500, rep);
                cts.estimate(&cts.sketch(&t), &target)
            })
            .collect();
        let m = mean(&est);
        let spread = (crate::util::stats::variance(&est) / reps as f64).sqrt();
        assert!((m - truth).abs() < 5.0 * spread.max(0.02), "{m} vs {truth}");
    }

    #[test]
    fn mts_estimate_unbiased() {
        let t = small_tucker(3);
        let dense = t.reconstruct();
        let target = [0usize, 3, 5];
        let truth = dense.get(&target);
        let reps = 2500;
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let mts = MtsTucker::with_repeat(&[6, 6, 6], &[2, 2, 2], 8, 8, 900, rep);
                mts.estimate(&mts.sketch(&t), &target)
            })
            .collect();
        let m = mean(&est);
        let spread = (crate::util::stats::variance(&est) / reps as f64).sqrt();
        assert!((m - truth).abs() < 5.0 * spread.max(0.02), "{m} vs {truth}");
    }

    #[test]
    fn median_of_d_recovery_improves_with_sketch_size() {
        let t = small_tucker(4);
        let dense = t.reconstruct();
        let err_for = |m1: usize| {
            let errs: Vec<f64> = (0..5)
                .map(|rep| {
                    let mts = MtsTucker::with_repeat(&[6, 6, 6], &[2, 2, 2], m1, 16, 77, rep);
                    let rec = mts.decompress(&mts.sketch(&t));
                    crate::tensor::rel_error(&dense, &rec)
                })
                .collect();
            median(&errs)
        };
        let e_small = err_for(8);
        let e_big = err_for(128);
        assert!(e_big < e_small, "m1=8→{e_small}, m1=128→{e_big}");
    }

    #[test]
    fn mts_core_sketch_diagonal_matches_full() {
        // a diagonal core sketched via sketch_core equals sketching the
        // dense core (CP-consistency check)
        let ranks = [3usize, 3, 3];
        let mts = MtsTucker::new(&[5, 5, 5], &ranks, 4, 4, 5);
        let mut core = Tensor::zeros(&ranks);
        for i in 0..3 {
            core.set(&[i, i, i], (i + 1) as f64);
        }
        let got = mts.sketch_core(&core);
        // direct
        let mut want = vec![0.0; 4];
        for i in 0..3 {
            let mut b = 0usize;
            let mut s = 1.0;
            for k in 0..3 {
                b += mts.factor_sk[k].mode(1).h(i);
                s *= mts.factor_sk[k].mode(1).s(i);
            }
            want[b % 4] += s * (i + 1) as f64;
        }
        assert_eq!(got, want);
    }

    #[test]
    fn fourth_order_tucker_sketch() {
        let mut rng = Pcg64::new(6);
        let t = TuckerTensor::random(&[4, 4, 4, 4], &[2, 2, 2, 2], &mut rng);
        let cts = CtsTucker::new(&[4, 4, 4, 4], 32, 8);
        let sk = cts.sketch(&t);
        assert_eq!(sk.len(), 32);
        let mts = MtsTucker::new(&[4, 4, 4, 4], &[2, 2, 2, 2], 16, 8, 8);
        let sk2 = mts.sketch(&t);
        assert_eq!(sk2.len(), 16);
        // shapes + finite values
        assert!(sk.iter().chain(sk2.iter()).all(|x| x.is_finite()));
    }
}
