//! Sketched CP-ALS — the Wang et al. (2015) idea the paper builds on
//! ("fast and guaranteed tensor decomposition via sketching"), here in
//! its least-squares form: each ALS subproblem
//!
//! `min_{U_k} ‖ KR(U_{≠k}) · U_kᵀ − T_(k)ᵀ ‖_F`
//!
//! is solved on a **count-sketched row space**: the long axis
//! (∏_{j≠k} n_j rows) is compressed to `c` buckets with a CS, shrinking
//! the QR solve from O(∏n · r²) to O(c·r²) while keeping the solution
//! unbiased in expectation (CS is an oblivious subspace embedding for
//! c = Ω(r²/ε²)).

use super::cp::{khatri_rao, CpTensor};
use crate::hash::ModeHash;
use crate::linalg::lstsq;
use crate::rng::Pcg64;
use crate::tensor::Tensor;

/// CS a matrix's rows: `S·A` where S is the c×N count-sketch operator.
fn cs_rows(a: &Tensor, mh: &ModeHash) -> Tensor {
    let (n, cols) = (a.dims()[0], a.dims()[1]);
    assert_eq!(mh.n, n);
    let mut out = Tensor::zeros(&[mh.m, cols]);
    let od = out.data_mut();
    let ad = a.data();
    for i in 0..n {
        let b = mh.h(i);
        let s = mh.s(i);
        for j in 0..cols {
            od[b * cols + j] += s * ad[i * cols + j];
        }
    }
    out
}

/// CP decomposition via ALS with count-sketched least squares.
///
/// `c` is the sketch size per subproblem (≥ ~4r² recommended); the
/// hashes are redrawn every sweep (fresh randomness keeps the iteration
/// from locking onto one embedding's nullspace).
pub fn cp_als_sketched(
    t: &Tensor,
    r: usize,
    c: usize,
    max_iters: usize,
    tol: f64,
    rng: &mut Pcg64,
) -> CpTensor {
    let n_modes = t.order();
    let mut factors: Vec<Tensor> =
        t.dims().iter().map(|&d| Tensor::randn(&[d, r], rng)).collect();
    let mut prev_err = f64::INFINITY;
    for _sweep in 0..max_iters {
        for k in 0..n_modes {
            let others: Vec<&Tensor> =
                (0..n_modes).filter(|&j| j != k).map(|j| &factors[j]).collect();
            let kr = khatri_rao(&others); // N × r, N = ∏_{j≠k} n_j
            let unf_t = t.unfold(k).transpose(); // N × n_k
            let big_n = kr.dims()[0];
            let ceff = c.min(big_n);
            let mh = ModeHash::new(big_n, ceff, rng.next_u64());
            let skr = cs_rows(&kr, &mh); // c × r
            let sb = cs_rows(&unf_t, &mh); // c × n_k
            // guard: sketched system can be rank-deficient for tiny c
            let x = lstsq(&skr, &sb); // r × n_k
            factors[k] = x.transpose();
        }
        let fit = crate::tensor::rel_error(
            t,
            &CpTensor::new(vec![1.0; r], factors.clone()).reconstruct(),
        );
        if fit < tol || (prev_err - fit).abs() < tol {
            break;
        }
        prev_err = fit;
    }
    CpTensor::new(vec![1.0; r], factors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rel_error;

    #[test]
    fn recovers_exact_low_rank() {
        let mut rng = Pcg64::new(1);
        let src = CpTensor::random(&[8, 7, 6], 2, &mut rng);
        let dense = src.reconstruct();
        // generous sketch: c = 32 ≥ 4r²
        let fit = cp_als_sketched(&dense, 2, 32, 60, 1e-9, &mut rng);
        let err = rel_error(&dense, &fit.reconstruct());
        assert!(err < 0.05, "err {err}");
    }

    #[test]
    fn sketch_size_quality_tradeoff() {
        let mut rng = Pcg64::new(2);
        let src = CpTensor::random(&[10, 10, 10], 3, &mut rng);
        let dense = src.reconstruct();
        let err_for = |c: usize, seed: u64| {
            let mut r2 = Pcg64::new(seed);
            let fit = cp_als_sketched(&dense, 3, c, 40, 1e-9, &mut r2);
            rel_error(&dense, &fit.reconstruct())
        };
        // median over a few seeds for stability
        let small: Vec<f64> = (0..3).map(|s| err_for(12, 100 + s)).collect();
        let large: Vec<f64> = (0..3).map(|s| err_for(100, 200 + s)).collect();
        let ms = crate::util::stats::median(&small);
        let ml = crate::util::stats::median(&large);
        assert!(ml <= ms + 0.05, "larger sketch shouldn't be worse: {ms} vs {ml}");
        assert!(ml < 0.2, "large-sketch fit should be good: {ml}");
    }

    #[test]
    fn sketched_system_shapes() {
        let mut rng = Pcg64::new(3);
        let a = Tensor::randn(&[50, 4], &mut rng);
        let mh = ModeHash::new(50, 16, 9);
        let s = cs_rows(&a, &mh);
        assert_eq!(s.dims(), &[16, 4]);
        // CS preserves column sums up to signs: ‖S·A‖_F ≈ ‖A‖_F in expectation
        assert!(s.fro_norm() > 0.0);
    }
}
