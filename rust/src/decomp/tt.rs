//! Tensor-train decomposition (Oseledets 2011).
//!
//! Cores are stored as order-3 tensors `G_k ∈ ℝ^{r_{k-1} × n_k × r_k}`
//! with `r_0 = r_N = 1`. For the third-order case the paper writes
//! `T[i,j,k] = G1[i,:,:] · G2[j,:,:] · G3[k,:,:]` with
//! `G1 ∈ ℝ^{n1×r1}`, `G2 ∈ ℝ^{n2×r1×r2}`, `G3 ∈ ℝ^{n3×r2}`; accessors
//! below expose that layout for the sketch layer.

use crate::linalg::svd;
use crate::rng::Pcg64;
use crate::tensor::Tensor;

/// Tensor-train tensor with cores `G_k ∈ ℝ^{r_{k-1}×n_k×r_k}`.
#[derive(Clone, Debug)]
pub struct TtTensor {
    pub cores: Vec<Tensor>,
}

impl TtTensor {
    pub fn new(cores: Vec<Tensor>) -> Self {
        assert!(!cores.is_empty());
        assert_eq!(cores[0].dims()[0], 1, "first TT rank must be 1");
        assert_eq!(cores.last().unwrap().dims()[2], 1, "last TT rank must be 1");
        for w in cores.windows(2) {
            assert_eq!(
                w[0].dims()[2],
                w[1].dims()[0],
                "adjacent TT ranks must chain"
            );
        }
        Self { cores }
    }

    /// Random TT tensor with given dims and internal ranks
    /// (`ranks.len() == dims.len() - 1`).
    pub fn random(dims: &[usize], ranks: &[usize], rng: &mut Pcg64) -> Self {
        assert_eq!(ranks.len() + 1, dims.len());
        let mut full_ranks = vec![1usize];
        full_ranks.extend_from_slice(ranks);
        full_ranks.push(1);
        let cores = dims
            .iter()
            .enumerate()
            .map(|(k, &n)| Tensor::randn(&[full_ranks[k], n, full_ranks[k + 1]], rng))
            .collect();
        Self::new(cores)
    }

    pub fn dims(&self) -> Vec<usize> {
        self.cores.iter().map(|c| c.dims()[1]).collect()
    }

    /// Internal ranks r₁ … r_{N-1}.
    pub fn ranks(&self) -> Vec<usize> {
        self.cores[..self.cores.len() - 1].iter().map(|c| c.dims()[2]).collect()
    }

    /// Exact dense reconstruction by sweeping left→right.
    pub fn reconstruct(&self) -> Tensor {
        // cur: (prod_dims_so_far) × r_k matrix
        let c0 = &self.cores[0];
        let (n0, r1) = (c0.dims()[1], c0.dims()[2]);
        let mut cur = c0.clone().reshape(&[n0, r1]);
        for core in &self.cores[1..] {
            let (rl, n, rr) = (core.dims()[0], core.dims()[1], core.dims()[2]);
            let mat = core.clone().reshape(&[rl, n * rr]);
            // (M × rl)·(rl × n·rr) = M × (n·rr)
            cur = cur.matmul(&mat);
            let m = cur.dims()[0];
            cur = cur.reshape(&[m * n, rr]);
        }
        let dims = self.dims();
        cur.reshape(&dims)
    }

    pub fn param_count(&self) -> usize {
        self.cores.iter().map(|c| c.len()).sum()
    }

    // ---------- third-order paper layout ----------

    /// `G1 ∈ ℝ^{n1×r1}` (paper's layout for third-order TT).
    pub fn g1_matrix(&self) -> Tensor {
        assert_eq!(self.cores.len(), 3, "paper layout is third-order");
        let c = &self.cores[0];
        c.clone().reshape(&[c.dims()[1], c.dims()[2]])
    }

    /// `G2 ∈ ℝ^{n2×r1×r2}` (mode order n, r1, r2).
    pub fn g2_tensor(&self) -> Tensor {
        assert_eq!(self.cores.len(), 3);
        self.cores[1].permute(&[1, 0, 2])
    }

    /// `G3 ∈ ℝ^{n3×r2}`.
    pub fn g3_matrix(&self) -> Tensor {
        assert_eq!(self.cores.len(), 3);
        let c = &self.cores[2];
        c.clone().reshape(&[c.dims()[0], c.dims()[1]]).transpose()
    }
}

/// TT-SVD: sequential truncated SVDs of the unfolding (Oseledets Alg. 1).
/// `ranks` are the target internal ranks (len = order-1); actual ranks
/// may come out smaller if the unfoldings are rank-deficient.
pub fn tt_svd(t: &Tensor, ranks: &[usize]) -> TtTensor {
    let dims = t.dims().to_vec();
    let n = dims.len();
    assert_eq!(ranks.len() + 1, n);
    let mut cores = Vec::with_capacity(n);
    let mut rprev = 1usize;
    // c: remaining tensor flattened as (rprev·n_k) × rest
    let mut c = t.clone().reshape(&[dims[0], t.len() / dims[0]]);
    for k in 0..n - 1 {
        let rows = rprev * dims[k];
        let cols = c.len() / rows;
        c = c.reshape(&[rows, cols]);
        let target = ranks[k].min(rows).min(cols);
        // truncated SVD
        let (u, s, v) = if rows >= cols {
            svd(&c)
        } else {
            let (u2, s2, v2) = svd(&c.transpose());
            (v2, s2, u2)
        };
        // effective rank: drop numerically-zero directions
        let cutoff = s.first().copied().unwrap_or(0.0) * 1e-12;
        let reff = s.iter().take(target).filter(|&&x| x > cutoff).count().max(1);
        // U_trunc: rows × reff → core
        let mut core = Tensor::zeros(&[rprev, dims[k], reff]);
        for i in 0..rows {
            for j in 0..reff {
                core.set(&[i / dims[k], i % dims[k], j], u.at2(i, j));
            }
        }
        cores.push(core);
        // carry = diag(s)·Vᵀ restricted to reff: reff × cols
        let mut carry = Tensor::zeros(&[reff, cols]);
        for i in 0..reff {
            for j in 0..cols {
                carry.set(&[i, j], s[i] * v.at2(j, i));
            }
        }
        c = carry;
        rprev = reff;
    }
    // last core: rprev × n_{N-1} × 1
    let last = c.reshape(&[rprev, dims[n - 1], 1]);
    cores.push(last);
    TtTensor::new(cores)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rel_error;

    #[test]
    fn random_tt_shapes_and_params() {
        let mut rng = Pcg64::new(1);
        let t = TtTensor::random(&[4, 5, 6], &[2, 3], &mut rng);
        assert_eq!(t.dims(), vec![4, 5, 6]);
        assert_eq!(t.ranks(), vec![2, 3]);
        assert_eq!(t.param_count(), 1 * 4 * 2 + 2 * 5 * 3 + 3 * 6 * 1);
        assert_eq!(t.reconstruct().dims(), &[4, 5, 6]);
    }

    #[test]
    fn reconstruct_matches_paper_elementwise_formula() {
        // T[i,j,k] = G1[i,:] · G2[j,:,:] · G3[k,:]
        let mut rng = Pcg64::new(2);
        let tt = TtTensor::random(&[3, 4, 5], &[2, 3], &mut rng);
        let full = tt.reconstruct();
        let g1 = tt.g1_matrix(); // n1 × r1
        let g2 = tt.g2_tensor(); // n2 × r1 × r2
        let g3 = tt.g3_matrix(); // n3 × r2
        for i in 0..3 {
            for j in 0..4 {
                for k in 0..5 {
                    let mut want = 0.0;
                    for a in 0..2 {
                        for b in 0..3 {
                            want += g1.at2(i, a) * g2.get(&[j, a, b]) * g3.at2(k, b);
                        }
                    }
                    assert!(
                        (full.get(&[i, j, k]) - want).abs() < 1e-10,
                        "({i},{j},{k})"
                    );
                }
            }
        }
    }

    #[test]
    fn tt_svd_exact_on_tt_structured_input() {
        let mut rng = Pcg64::new(3);
        let src = TtTensor::random(&[5, 6, 4], &[2, 2], &mut rng);
        let full = src.reconstruct();
        let dec = tt_svd(&full, &[2, 2]);
        assert!(rel_error(&full, &dec.reconstruct()) < 1e-8);
    }

    #[test]
    fn tt_svd_full_rank_lossless() {
        let mut rng = Pcg64::new(4);
        let t = Tensor::randn(&[3, 4, 3], &mut rng);
        // max useful ranks: r1 ≤ min(3, 12), r2 ≤ min(12, 3)
        let dec = tt_svd(&t, &[3, 3]);
        assert!(rel_error(&t, &dec.reconstruct()) < 1e-8);
    }

    #[test]
    fn tt_svd_truncation_monotone() {
        let mut rng = Pcg64::new(5);
        let t = Tensor::randn(&[4, 5, 4], &mut rng);
        let e1 = rel_error(&t, &tt_svd(&t, &[1, 1]).reconstruct());
        let e2 = rel_error(&t, &tt_svd(&t, &[2, 2]).reconstruct());
        let e4 = rel_error(&t, &tt_svd(&t, &[4, 4]).reconstruct());
        assert!(e1 >= e2 - 1e-10 && e2 >= e4 - 1e-10, "{e1} {e2} {e4}");
    }

    #[test]
    fn tt_svd_fourth_order() {
        let mut rng = Pcg64::new(6);
        let src = TtTensor::random(&[3, 4, 4, 3], &[2, 3, 2], &mut rng);
        let full = src.reconstruct();
        let dec = tt_svd(&full, &[2, 3, 2]);
        assert!(rel_error(&full, &dec.reconstruct()) < 1e-8);
    }
}
