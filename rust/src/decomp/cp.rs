//! CP (CANDECOMP/PARAFAC) decomposition:
//! `T = Σ_{i=1}^r λ_i · U₁[:,i] ⊗ ⋯ ⊗ U_N[:,i]`.
//!
//! The paper treats CP as the diagonal-core special case of Tucker
//! (§3.1 REMARKS); the sketch layer consumes [`CpTensor`] directly.

use crate::linalg::lstsq;
use crate::rng::Pcg64;
use crate::tensor::{kron_vec, outer, Tensor};

/// CP-form tensor: weights λ ∈ ℝ^r and factors `U_k ∈ ℝ^{n_k×r}`.
#[derive(Clone, Debug)]
pub struct CpTensor {
    pub weights: Vec<f64>,
    pub factors: Vec<Tensor>,
}

impl CpTensor {
    pub fn new(weights: Vec<f64>, factors: Vec<Tensor>) -> Self {
        let r = weights.len();
        for (k, f) in factors.iter().enumerate() {
            assert_eq!(f.order(), 2, "factor {k} must be a matrix");
            assert_eq!(f.dims()[1], r, "factor {k} cols {} != rank {r}", f.dims()[1]);
        }
        Self { weights, factors }
    }

    /// Random rank-`r` CP tensor (unit weights, normal factors).
    /// Supports the overcomplete regime r > n the paper highlights.
    pub fn random(dims: &[usize], r: usize, rng: &mut Pcg64) -> Self {
        let factors = dims.iter().map(|&n| Tensor::randn(&[n, r], rng)).collect();
        Self::new(vec![1.0; r], factors)
    }

    pub fn rank(&self) -> usize {
        self.weights.len()
    }

    pub fn dims(&self) -> Vec<usize> {
        self.factors.iter().map(|f| f.dims()[0]).collect()
    }

    /// Exact dense reconstruction.
    pub fn reconstruct(&self) -> Tensor {
        let dims = self.dims();
        let mut out = Tensor::zeros(&dims);
        for (i, &w) in self.weights.iter().enumerate() {
            let cols: Vec<Vec<f64>> = self.factors.iter().map(|f| f.col(i)).collect();
            let views: Vec<&[f64]> = cols.iter().map(|c| c.as_slice()).collect();
            let t = outer(&views).scale(w);
            out.add_assign(&t);
        }
        out
    }

    /// Parameter count (Table 5's exact-form memory O(nr + r)).
    pub fn param_count(&self) -> usize {
        self.weights.len() + self.factors.iter().map(|f| f.len()).sum::<usize>()
    }

    /// View as a Tucker tensor with (sparse) diagonal core — used by the
    /// sketch layer's shared code path.
    pub fn to_tucker(&self) -> super::TuckerTensor {
        let r = self.rank();
        let n = self.factors.len();
        let mut core = Tensor::zeros(&vec![r; n]);
        for (i, &w) in self.weights.iter().enumerate() {
            let idx = vec![i; n];
            core.set(&idx, w);
        }
        super::TuckerTensor::new(core, self.factors.clone())
    }
}

/// Khatri–Rao product (column-wise Kronecker) of matrices (n_k × r) for
/// k in `mats`, in the given order: output (∏ n_k) × r.
pub fn khatri_rao(mats: &[&Tensor]) -> Tensor {
    assert!(!mats.is_empty());
    let r = mats[0].dims()[1];
    for m in mats {
        assert_eq!(m.dims()[1], r);
    }
    let mut rows = 1usize;
    for m in mats {
        rows *= m.dims()[0];
    }
    let mut out = Tensor::zeros(&[rows, r]);
    for j in 0..r {
        let mut col = vec![1.0];
        for m in mats {
            col = kron_vec(&col, &m.col(j));
        }
        for (i, &v) in col.iter().enumerate() {
            out.set(&[i, j], v);
        }
    }
    out
}

/// CP decomposition via alternating least squares. Returns the fitted
/// [`CpTensor`]; iterates until relative fit change < `tol` or
/// `max_iters`.
pub fn cp_als(t: &Tensor, r: usize, max_iters: usize, tol: f64, rng: &mut Pcg64) -> CpTensor {
    let n = t.order();
    let mut factors: Vec<Tensor> =
        t.dims().iter().map(|&d| Tensor::randn(&[d, r], rng)).collect();
    let mut prev_fit = f64::INFINITY;
    let tnorm = t.fro_norm().max(1e-300);
    for _ in 0..max_iters {
        for k in 0..n {
            // T_(k) = U_k · (KR of others in reverse mode order)ᵀ
            // With Kolda unfolding (remaining modes in original order,
            // row-major = last fastest), the matching KR order is the
            // *original order* of the other modes.
            let others: Vec<&Tensor> =
                (0..n).filter(|&j| j != k).map(|j| &factors[j]).collect();
            let kr = khatri_rao(&others); // (∏_{j≠k} n_j) × r
            let unf = t.unfold(k); // n_k × ∏ n_j
            // solve K x = unfᵀ  →  factor row space; x: r × n_k
            let x = lstsq(&kr, &unf.transpose());
            factors[k] = x.transpose();
        }
        let fit = crate::tensor::rel_error(
            t,
            &CpTensor::new(vec![1.0; r], factors.clone()).reconstruct(),
        );
        if (prev_fit - fit).abs() < tol * tnorm.max(1.0) || fit < tol {
            prev_fit = fit;
            break;
        }
        prev_fit = fit;
    }
    let _ = prev_fit;
    CpTensor::new(vec![1.0; r], factors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rel_error;

    #[test]
    fn reconstruct_matches_formula() {
        let mut rng = Pcg64::new(1);
        let cp = CpTensor::random(&[3, 4, 5], 2, &mut rng);
        let full = cp.reconstruct();
        for i in 0..3 {
            for j in 0..4 {
                for k in 0..5 {
                    let mut want = 0.0;
                    for c in 0..2 {
                        want += cp.factors[0].at2(i, c)
                            * cp.factors[1].at2(j, c)
                            * cp.factors[2].at2(k, c);
                    }
                    assert!((full.get(&[i, j, k]) - want).abs() < 1e-10);
                }
            }
        }
    }

    #[test]
    fn to_tucker_reconstruction_agrees() {
        let mut rng = Pcg64::new(2);
        let cp = CpTensor::random(&[4, 3, 5], 3, &mut rng);
        let a = cp.reconstruct();
        let b = cp.to_tucker().reconstruct();
        assert!(rel_error(&a, &b) < 1e-10);
    }

    #[test]
    fn khatri_rao_known_values() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let b = Tensor::from_vec(vec![5.0, 6.0, 7.0, 8.0], &[2, 2]);
        let kr = khatri_rao(&[&a, &b]);
        assert_eq!(kr.dims(), &[4, 2]);
        // col0 = [1,3]⊗[5,7] = [5,7,15,21]; col1 = [2,4]⊗[6,8]=[12,16,24,32]
        assert_eq!(kr.col(0), vec![5.0, 7.0, 15.0, 21.0]);
        assert_eq!(kr.col(1), vec![12.0, 16.0, 24.0, 32.0]);
    }

    #[test]
    fn unfolding_kr_identity() {
        // T = Σ u_c ⊗ v_c ⊗ w_c ⇒ T_(0) = U · KR(V, W)ᵀ
        let mut rng = Pcg64::new(3);
        let cp = CpTensor::random(&[3, 4, 2], 2, &mut rng);
        let t = cp.reconstruct();
        let kr = khatri_rao(&[&cp.factors[1], &cp.factors[2]]);
        let want = cp.factors[0].matmul(&kr.transpose());
        let got = t.unfold(0);
        assert!(rel_error(&want, &got) < 1e-10);
    }

    #[test]
    fn cp_als_recovers_exact_low_rank() {
        let mut rng = Pcg64::new(4);
        let src = CpTensor::random(&[6, 5, 7], 2, &mut rng);
        let full = src.reconstruct();
        let fit = cp_als(&full, 2, 60, 1e-10, &mut rng);
        let err = rel_error(&full, &fit.reconstruct());
        assert!(err < 1e-6, "err={err}");
    }

    #[test]
    fn cp_als_overcomplete_representation() {
        // overcomplete regime r > n: ALS should still drive error down
        let mut rng = Pcg64::new(5);
        let src = CpTensor::random(&[4, 4, 4], 6, &mut rng);
        let full = src.reconstruct();
        let fit = cp_als(&full, 6, 80, 1e-10, &mut rng);
        let err = rel_error(&full, &fit.reconstruct());
        assert!(err < 0.2, "err={err}");
    }

    #[test]
    fn param_count() {
        let mut rng = Pcg64::new(6);
        let cp = CpTensor::random(&[5, 6, 7], 3, &mut rng);
        assert_eq!(cp.param_count(), 3 + 3 * (5 + 6 + 7));
    }
}
