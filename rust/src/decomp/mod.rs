//! Tensor decomposition substrate — produces the Tucker / CP / TT forms
//! the sketch layer consumes (§3 of the paper), and serves as the exact
//! reconstruction reference in benchmarks.
//!
//! - [`TuckerTensor`] + [`hosvd`] — higher-order SVD (the "higher-order
//!   PCA" the paper references).
//! - [`CpTensor`] + [`cp_als`] — CANDECOMP/PARAFAC via alternating least
//!   squares.
//! - [`TtTensor`] + [`tt_svd`] — tensor-train via sequential truncated
//!   SVDs (Oseledets 2011).

pub mod cp;
pub mod sketched_cp;
pub mod tt;
pub mod tucker;

pub use cp::{cp_als, CpTensor};
pub use sketched_cp::cp_als_sketched;
pub use tt::{tt_svd, TtTensor};
pub use tucker::{hosvd, TuckerTensor};
