//! Tucker decomposition: `T = G(U₁, …, U_N)` (paper Eq. 1).

use crate::linalg::leading_left_singular;
use crate::rng::Pcg64;
use crate::tensor::{mode_k_product, Tensor};

/// Tucker-form tensor: core `G ∈ ℝ^{r₁×⋯×r_N}` and factors
/// `U_k ∈ ℝ^{n_k×r_k}`.
#[derive(Clone, Debug)]
pub struct TuckerTensor {
    pub core: Tensor,
    pub factors: Vec<Tensor>,
}

impl TuckerTensor {
    pub fn new(core: Tensor, factors: Vec<Tensor>) -> Self {
        assert_eq!(core.order(), factors.len(), "one factor per core mode");
        for (k, f) in factors.iter().enumerate() {
            assert_eq!(f.order(), 2, "factor {k} must be a matrix");
            assert_eq!(
                f.dims()[1],
                core.dims()[k],
                "factor {k} cols {} != core dim {}",
                f.dims()[1],
                core.dims()[k]
            );
        }
        Self { core, factors }
    }

    /// Random Tucker-form tensor with iid normal core and factors.
    pub fn random(dims: &[usize], ranks: &[usize], rng: &mut Pcg64) -> Self {
        assert_eq!(dims.len(), ranks.len());
        let core = Tensor::randn(ranks, rng);
        let factors = dims
            .iter()
            .zip(ranks.iter())
            .map(|(&n, &r)| Tensor::randn(&[n, r], rng))
            .collect();
        Self::new(core, factors)
    }

    /// Ambient dimensions n₁…n_N.
    pub fn dims(&self) -> Vec<usize> {
        self.factors.iter().map(|f| f.dims()[0]).collect()
    }

    /// Multilinear ranks r₁…r_N.
    pub fn ranks(&self) -> Vec<usize> {
        self.core.dims().to_vec()
    }

    /// Exact dense reconstruction `G ×₁ U₁ ⋯ ×_N U_N`.
    pub fn reconstruct(&self) -> Tensor {
        let mut cur = self.core.clone();
        for (k, f) in self.factors.iter().enumerate() {
            // contract core mode k (size r_k) with fᵀ: need matrix r_k×n_k
            cur = mode_k_product(&cur, &f.transpose(), k);
        }
        cur
    }

    /// Parameter count (the "memory" column of Table 5 for the exact
    /// form: O(nr + r³)).
    pub fn param_count(&self) -> usize {
        self.core.len() + self.factors.iter().map(|f| f.len()).sum::<usize>()
    }
}

/// Higher-order SVD: factor `U_k` = leading `r_k` left singular vectors
/// of the mode-k unfolding; core = `T(U₁ᵀ, …)`.
pub fn hosvd(t: &Tensor, ranks: &[usize]) -> TuckerTensor {
    assert_eq!(ranks.len(), t.order());
    let factors: Vec<Tensor> = (0..t.order())
        .map(|k| leading_left_singular(&t.unfold(k), ranks[k]))
        .collect();
    let mut core = t.clone();
    for (k, f) in factors.iter().enumerate() {
        core = mode_k_product(&core, f, k); // contract n_k with U_k → r_k
    }
    TuckerTensor::new(core, factors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rel_error;

    #[test]
    fn random_tucker_shapes() {
        let mut rng = Pcg64::new(1);
        let t = TuckerTensor::random(&[6, 7, 8], &[2, 3, 4], &mut rng);
        assert_eq!(t.dims(), vec![6, 7, 8]);
        assert_eq!(t.ranks(), vec![2, 3, 4]);
        let full = t.reconstruct();
        assert_eq!(full.dims(), &[6, 7, 8]);
        assert_eq!(t.param_count(), 2 * 3 * 4 + 6 * 2 + 7 * 3 + 8 * 4);
    }

    #[test]
    fn reconstruct_matches_elementwise_formula() {
        let mut rng = Pcg64::new(2);
        let t = TuckerTensor::random(&[3, 4, 5], &[2, 2, 2], &mut rng);
        let full = t.reconstruct();
        let (u, v, w) = (&t.factors[0], &t.factors[1], &t.factors[2]);
        for i in 0..3 {
            for j in 0..4 {
                for k in 0..5 {
                    let mut want = 0.0;
                    for a in 0..2 {
                        for b in 0..2 {
                            for c in 0..2 {
                                want += t.core.get(&[a, b, c])
                                    * u.at2(i, a)
                                    * v.at2(j, b)
                                    * w.at2(k, c);
                            }
                        }
                    }
                    assert!((full.get(&[i, j, k]) - want).abs() < 1e-10);
                }
            }
        }
    }

    #[test]
    fn hosvd_exact_on_exactly_low_rank() {
        let mut rng = Pcg64::new(3);
        let src = TuckerTensor::random(&[8, 9, 7], &[2, 3, 2], &mut rng);
        let full = src.reconstruct();
        let dec = hosvd(&full, &[2, 3, 2]);
        let recon = dec.reconstruct();
        assert!(rel_error(&full, &recon) < 1e-8, "err={}", rel_error(&full, &recon));
    }

    #[test]
    fn hosvd_full_rank_is_lossless() {
        let mut rng = Pcg64::new(4);
        let t = Tensor::randn(&[4, 5, 3], &mut rng);
        let dec = hosvd(&t, &[4, 5, 3]);
        assert!(rel_error(&t, &dec.reconstruct()) < 1e-8);
    }

    #[test]
    fn hosvd_truncation_monotone() {
        // more rank → error not worse
        let mut rng = Pcg64::new(5);
        let t = Tensor::randn(&[6, 6, 6], &mut rng);
        let e2 = rel_error(&t, &hosvd(&t, &[2, 2, 2]).reconstruct());
        let e4 = rel_error(&t, &hosvd(&t, &[4, 4, 4]).reconstruct());
        let e6 = rel_error(&t, &hosvd(&t, &[6, 6, 6]).reconstruct());
        assert!(e2 >= e4 - 1e-10 && e4 >= e6 - 1e-10, "{e2} {e4} {e6}");
        assert!(e6 < 1e-8);
    }

    #[test]
    fn hosvd_factors_orthonormal() {
        let mut rng = Pcg64::new(6);
        let t = Tensor::randn(&[5, 6, 4], &mut rng);
        let dec = hosvd(&t, &[2, 3, 2]);
        for f in &dec.factors {
            let g = f.transpose().matmul(f);
            assert!(rel_error(&Tensor::eye(f.dims()[1]), &g) < 1e-9);
        }
    }
}
