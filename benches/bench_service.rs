//! `cargo bench` target: coordinator throughput/latency (§Perf L3) plus
//! the L1 combine microbench (complex vs real-input FFT path).
//!
//! Writes `BENCH_service.json` — throughput + p50/p99 latency per
//! worker count × batch size, and the combine speedup per sketch size —
//! so future PRs have a perf trajectory to compare against.
use hocs::experiments::{run_combine_bench, run_service_bench, ExpConfig};
use hocs::util::json::{self, Json};

const OUT_PATH: &str = "BENCH_service.json";

/// When the service bench cannot run (no artifacts), keep the service
/// rows from an earlier BENCH_service.json instead of clobbering the
/// perf trajectory with an empty array.
fn previous_service_rows() -> Option<Json> {
    let text = std::fs::read_to_string(OUT_PATH).ok()?;
    let prev = json::parse(&text).ok()?;
    prev.get("service").filter(|s| s.as_arr().is_some_and(|a| !a.is_empty())).cloned()
}

fn main() {
    // HOCS_BENCH_QUICK=1 (CI's bench-smoke job) runs the short sweep —
    // same rows and JSON schema, env-capped iteration counts
    let cfg = ExpConfig {
        quick: std::env::var("HOCS_BENCH_QUICK").is_ok(),
        ..ExpConfig::default()
    };
    if cfg.quick {
        println!("HOCS_BENCH_QUICK set: short sweep (CI smoke), same schema\n");
    }

    let (combine_table, combines) = run_combine_bench(&cfg);
    combine_table.print();
    println!();

    let service_rows = match run_service_bench(&cfg, "artifacts") {
        Ok((table, stats)) => {
            table.print();
            stats
        }
        Err(e) => {
            println!("service bench skipped: {e} (run `make artifacts`)");
            Vec::new()
        }
    };
    let service_json = if service_rows.is_empty() {
        previous_service_rows().unwrap_or(Json::Arr(Vec::new()))
    } else {
        Json::Arr(
            service_rows
                .iter()
                .map(|s| {
                    Json::obj(vec![
                        ("backend", Json::Str(s.backend.to_string())),
                        ("workers", Json::Num(s.workers as f64)),
                        ("max_batch", Json::Num(s.max_batch as f64)),
                        ("requests", Json::Num(s.requests as f64)),
                        ("wall_secs", Json::Num(s.wall_secs)),
                        ("throughput_rps", Json::Num(s.throughput)),
                        ("mean_latency_us", Json::Num(s.mean_latency_us)),
                        ("p50_latency_us", Json::Num(s.p50_latency_us as f64)),
                        ("p99_latency_us", Json::Num(s.p99_latency_us as f64)),
                        ("mean_batch", Json::Num(s.mean_batch)),
                    ])
                })
                .collect(),
        )
    };

    let json = Json::obj(vec![
        (
            "combine",
            Json::Arr(
                combines
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("m", Json::Num(c.m as f64)),
                            ("complex_us", Json::Num(c.complex_us)),
                            ("real_us", Json::Num(c.real_us)),
                            ("speedup", Json::Num(c.speedup)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("service", service_json),
    ]);
    match std::fs::write(OUT_PATH, json.to_string_pretty()) {
        Ok(()) => println!("\nwrote {OUT_PATH}"),
        Err(e) => eprintln!("could not write {OUT_PATH}: {e}"),
    }
}
