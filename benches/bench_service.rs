//! `cargo bench` target: coordinator throughput/latency (§Perf L3).
use hocs::experiments::{run_service_bench, ExpConfig};

fn main() {
    match run_service_bench(&ExpConfig::default(), "artifacts") {
        Ok((table, _)) => table.print(),
        Err(e) => println!("service bench skipped: {e} (run `make artifacts`)"),
    }
}
