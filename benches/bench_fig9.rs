//! `cargo bench` target: Figure 9 (covariance estimation).
use hocs::experiments::{run_fig9, ExpConfig};

fn main() {
    let (table, _) = run_fig9(&ExpConfig::default());
    table.print();
}
