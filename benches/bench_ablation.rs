//! `cargo bench` target: design-choice ablations (sketch path, FFT
//! packing, batching policy, median-of-d).
use hocs::experiments::{
    run_ablation_batching, run_ablation_fft_packing, run_ablation_median_d,
    run_ablation_sketch_path, ExpConfig,
};

fn main() {
    let cfg = ExpConfig::default();
    run_ablation_sketch_path(&cfg).print();
    println!();
    run_ablation_fft_packing(&cfg).print();
    println!();
    run_ablation_median_d(&cfg).print();
    println!();
    match run_ablation_batching(&cfg, "artifacts") {
        Ok(t) => t.print(),
        Err(e) => println!("batching ablation skipped: {e}"),
    }
}
