//! `cargo bench` target: Figures 10 & 12 (tensor-regression network,
//! end to end through the AOT artifacts). Uses a shortened schedule so
//! `cargo bench` stays tractable; the full curves come from
//! `hocs bench fig10` / `examples/train_trl.rs`.
use hocs::experiments::{run_fig10, run_fig12, ExpConfig};
use hocs::runtime::Runtime;

fn main() {
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("train bench skipped: {e} (run `make artifacts`)");
            return;
        }
    };
    let cfg = ExpConfig { quick: true, ..Default::default() };
    match run_fig10(&cfg, &rt) {
        Ok((t, _)) => t.print(),
        Err(e) => println!("fig10 failed: {e}"),
    }
    println!();
    match run_fig12(&cfg, &rt) {
        Ok((t, _)) => t.print(),
        Err(e) => println!("fig12 failed: {e}"),
    }
}
