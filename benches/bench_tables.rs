//! `cargo bench` target: Tables 1 / 3 / 4-5 / 6 (sketched tensor-op
//! computation & memory, CTS vs MTS).
use hocs::experiments::{run_table1, run_table3, run_table45, run_table6, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    run_table3(&cfg, &[8, 12, 16, 24, 32]).0.print();
    println!();
    run_table45(&cfg, &[(12, 2), (12, 4), (16, 6), (8, 10), (6, 12)]).0.print();
    println!();
    run_table6(&cfg, &[(12, 2), (16, 4), (16, 8), (8, 12)]).0.print();
    println!();
    run_table1(&cfg).print();
}
