//! `cargo bench` target: Theorem 2.1 empirical variance check.
use hocs::experiments::{run_variance, ExpConfig};

fn main() {
    let (table, _) = run_variance(&ExpConfig::default());
    table.print();
}
