//! Tensor-store headline bench: order-3 HCS vs a flat count sketch.
//!
//! Two experiments, both against exact dense oracles:
//!
//! 1. **Memory at matched error** — an order-3 HCS and a flat count
//!    sketch over the flattened key space ingest the same stream with
//!    the same counter budget (`Π m_k` buckets × d repeats) and the
//!    point-query error of both is measured against a dense array.
//!    Hash state is accounted at the hot-path tabulated representation
//!    (`ModeHash::bucket_table`/`sign_table`: one u32 bucket plus one
//!    f64 sign per input index, per repeat): HCS tabulates `Σ n_k`
//!    entries per repeat where the flat sketch tabulates `Π n_k` — the
//!    paper's structural memory win. The bench asserts HCS total bytes
//!    ≤ 1/4 of flat CS while staying within 4× of its measured error.
//! 2. **CONTRACT accuracy** — `⟨A, B⟩` estimated by `contract_scalar`
//!    on same-family sketches vs the exact dense inner product, with
//!    the absolute error asserted within the Ahle–Knudsen-style bound
//!    `8·‖A‖·‖B‖/√(Π m_k)`.
//!
//! Writes `BENCH_tensor.json`. `HOCS_BENCH_QUICK=1` shrinks problem
//! sizes (CI) — the JSON schema is identical in both modes.

use hocs::rng::Pcg64;
use hocs::store::tensor::{contract_scalar, HcsStream};
use hocs::util::bench::Table;
use hocs::util::json::Json;

const OUT_PATH: &str = "BENCH_tensor.json";

/// Repeats for every sketch in this bench (median-of-d estimation).
const D: usize = 5;

/// Memory headline floor asserted per row: flat CS bytes must be at
/// least this multiple of HCS bytes (ISSUE acceptance: HCS ≤ 1/4).
const MEM_RATIO_FLOOR: f64 = 4.0;

/// Matched-error slack: HCS point-query MAE may exceed the flat CS MAE
/// by at most this factor (both use the same counter budget; per-mode
/// hashing correlates partial collisions, costing a small constant).
const ERR_SLACK: f64 = 4.0;

fn quick() -> bool {
    std::env::var("HOCS_BENCH_QUICK").is_ok()
}

/// Total bytes of one sketch family: `Π m_k · d` f64 counters plus the
/// tabulated per-mode hashes (`Σ n_k` entries × d repeats × (u32 bucket
/// + f64 sign)).
fn sketch_bytes(dims: &[usize], sketch_dims: &[usize], d: usize) -> f64 {
    let counters = sketch_dims.iter().product::<usize>() * d * 8;
    let hashes = dims.iter().sum::<usize>() * d * (4 + 8);
    (counters + hashes) as f64
}

fn flatten(dims: &[usize], key: &[usize]) -> usize {
    let mut idx = 0;
    for (i, (&k, &n)) in key.iter().zip(dims.iter()).enumerate() {
        debug_assert!(k < n, "key out of range at mode {i}");
        idx = idx * n + k;
    }
    idx
}

fn random_key(rng: &mut Pcg64, dims: &[usize]) -> Vec<usize> {
    dims.iter().map(|&n| rng.gen_range(n as u64) as usize).collect()
}

struct MemRow {
    dims: Vec<usize>,
    sketch_dims: Vec<usize>,
    updates: usize,
    hcs_bytes: f64,
    flat_bytes: f64,
    hcs_mae: f64,
    flat_mae: f64,
}

impl MemRow {
    fn ratio(&self) -> f64 {
        self.flat_bytes / self.hcs_bytes
    }
}

/// Feed one stream (a few heavy keys over uniform background) into an
/// order-3 HCS and a flat CS with the same counter budget; measure
/// point-query MAE for both against the dense oracle.
fn run_mem_row(dims: &[usize], sketch_dims: &[usize], updates: usize, samples: usize) -> MemRow {
    let space: usize = dims.iter().product();
    let flat_m: usize = sketch_dims.iter().product();
    let mut dense = vec![0.0f64; space];
    let mut hcs = HcsStream::new(dims, sketch_dims, D, 42);
    let mut flat = HcsStream::new(&[space], &[flat_m], D, 4242);

    let mut rng = Pcg64::new(0xB_E4C); // stream generator, independent of both sketches
    let heavy: Vec<Vec<usize>> = (0..24).map(|_| random_key(&mut rng, dims)).collect();
    for step in 0..updates {
        let key = if step % 4 == 0 {
            heavy[rng.gen_range(heavy.len() as u64) as usize].clone()
        } else {
            random_key(&mut rng, dims)
        };
        let fk = flatten(dims, &key);
        dense[fk] += 1.0;
        hcs.update(&key, 1.0);
        flat.update(&[fk], 1.0);
    }

    // error sample: every heavy key plus `samples` uniform keys
    let mut probe: Vec<Vec<usize>> = heavy.clone();
    probe.extend((0..samples).map(|_| random_key(&mut rng, dims)));
    let (mut hcs_mae, mut flat_mae) = (0.0, 0.0);
    for key in &probe {
        let truth = dense[flatten(dims, key)];
        hcs_mae += (hcs.query(key) - truth).abs();
        flat_mae += (flat.query(&[flatten(dims, key)]) - truth).abs();
    }
    hcs_mae /= probe.len() as f64;
    flat_mae /= probe.len() as f64;

    MemRow {
        dims: dims.to_vec(),
        sketch_dims: sketch_dims.to_vec(),
        updates,
        hcs_bytes: sketch_bytes(dims, sketch_dims, D),
        flat_bytes: sketch_bytes(&[space], &[flat_m], D),
        hcs_mae,
        flat_mae,
    }
}

struct ContractRow {
    dims: Vec<usize>,
    sketch_dims: Vec<usize>,
    norm_a: f64,
    norm_b: f64,
    true_ip: f64,
    est_ip: f64,
    bound_abs: f64,
}

impl ContractRow {
    fn abs_err(&self) -> f64 {
        (self.est_ip - self.true_ip).abs()
    }

    fn rel_err(&self) -> f64 {
        self.abs_err() / (self.norm_a * self.norm_b)
    }
}

/// Sketch two random order-3 tensors into the same family, estimate
/// `⟨A, B⟩` with `contract_scalar`, and compare against the exact dense
/// inner product. B reuses A's support half the time so the true inner
/// product is well away from zero.
fn run_contract_row(dims: &[usize], sketch_dims: &[usize], per_tensor: usize) -> ContractRow {
    let space: usize = dims.iter().product();
    let mut dense_a = vec![0.0f64; space];
    let mut dense_b = vec![0.0f64; space];
    let mut sa = HcsStream::new(dims, sketch_dims, D, 42);
    let mut sb = HcsStream::new(dims, sketch_dims, D, 42);

    let mut rng = Pcg64::new(0xC0_17AC);
    let weight = |rng: &mut Pcg64| {
        let w = 1.0 + rng.gen_range(3) as f64;
        if rng.gen_range(2) == 0 {
            -w
        } else {
            w
        }
    };
    let mut a_keys = Vec::with_capacity(per_tensor);
    for _ in 0..per_tensor {
        let key = random_key(&mut rng, dims);
        let w = weight(&mut rng);
        dense_a[flatten(dims, &key)] += w;
        sa.update(&key, w);
        a_keys.push(key);
    }
    for _ in 0..per_tensor {
        let key = if rng.gen_range(2) == 0 {
            a_keys[rng.gen_range(a_keys.len() as u64) as usize].clone()
        } else {
            random_key(&mut rng, dims)
        };
        let w = weight(&mut rng);
        dense_b[flatten(dims, &key)] += w;
        sb.update(&key, w);
    }

    let norm_a = dense_a.iter().map(|x| x * x).sum::<f64>().sqrt();
    let norm_b = dense_b.iter().map(|x| x * x).sum::<f64>().sqrt();
    let true_ip: f64 = dense_a.iter().zip(dense_b.iter()).map(|(x, y)| x * y).sum();
    let m_prod: usize = sketch_dims.iter().product();
    ContractRow {
        dims: dims.to_vec(),
        sketch_dims: sketch_dims.to_vec(),
        norm_a,
        norm_b,
        true_ip,
        est_ip: contract_scalar(&sa, &sb),
        bound_abs: 8.0 * norm_a * norm_b / (m_prod as f64).sqrt(),
    }
}

struct KernelRow {
    batch: usize,
    scalar_per_sec: f64,
    kernel_per_sec: f64,
    speedup: f64,
}

/// ND fused batch walk: scalar oracle vs the two-phase kernel
/// (per-mode hash memoization + cache-blocked apply) on an order-3
/// stream. Batch 64 keeps every mode on the direct hash path; 8192
/// tabulates all of them. `HOCS_KERNEL=scalar` (the CI bit-identity
/// leg) collapses the speedup to ~1x with the same schema.
fn kernel_rows() -> Vec<KernelRow> {
    let dims = [1usize << 10, 1 << 10, 1 << 8];
    let mdims = [32usize, 32, 16];
    let total = if quick() { 200_000 } else { 2_000_000 };
    let mut rows = Vec::new();
    for batch in [64usize, 1024, 8192] {
        let reps = (total / batch).max(1);
        let mut rng = Pcg64::new(23);
        let mut keys = Vec::with_capacity(batch * dims.len());
        for _ in 0..batch {
            keys.extend(random_key(&mut rng, &dims));
        }
        let ws = vec![1.0f64; batch];

        let mut sk = HcsStream::new(&dims, &mdims, D, 42);
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            sk.update_batch_scalar(&keys, &ws);
        }
        let scalar = (reps * batch) as f64 / t0.elapsed().as_secs_f64();
        std::hint::black_box(sk.query(&[1, 1, 1]));
        let mut sk = HcsStream::new(&dims, &mdims, D, 42);
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            sk.update_batch(&keys, &ws);
        }
        let kernel = (reps * batch) as f64 / t0.elapsed().as_secs_f64();
        std::hint::black_box(sk.query(&[1, 1, 1]));
        rows.push(KernelRow {
            batch,
            scalar_per_sec: scalar,
            kernel_per_sec: kernel,
            speedup: kernel / scalar,
        });
    }
    rows
}

fn fmt_dims(dims: &[usize]) -> String {
    dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x")
}

fn main() {
    let mem_rows: Vec<MemRow> = if quick() {
        vec![run_mem_row(&[16, 16, 16], &[6, 6, 6], 6_000, 400)]
    } else {
        vec![
            run_mem_row(&[24, 24, 24], &[8, 8, 8], 20_000, 2_000),
            run_mem_row(&[32, 32, 32], &[8, 8, 8], 30_000, 2_000),
        ]
    };
    let contract_rows: Vec<ContractRow> = if quick() {
        vec![run_contract_row(&[10, 10, 10], &[6, 6, 6], 1_000)]
    } else {
        vec![
            run_contract_row(&[16, 16, 16], &[6, 6, 6], 3_000),
            run_contract_row(&[16, 16, 16], &[8, 8, 8], 3_000),
            run_contract_row(&[16, 16, 16], &[10, 10, 10], 3_000),
        ]
    };

    let mut t = Table::new(
        "order-3 HCS vs flat CS (same counter budget, same stream)",
        &["dims", "sketch", "updates", "hcs bytes", "flat bytes", "flat/hcs", "hcs mae", "flat mae"],
    );
    for r in &mem_rows {
        t.row(vec![
            fmt_dims(&r.dims),
            fmt_dims(&r.sketch_dims),
            r.updates.to_string(),
            format!("{:.0}", r.hcs_bytes),
            format!("{:.0}", r.flat_bytes),
            format!("{:.1}x", r.ratio()),
            format!("{:.2}", r.hcs_mae),
            format!("{:.2}", r.flat_mae),
        ]);
    }
    t.print();

    let mut t = Table::new(
        "CONTRACT <A,B> vs dense oracle",
        &["dims", "sketch", "true", "est", "abs err", "bound", "rel err"],
    );
    for r in &contract_rows {
        t.row(vec![
            fmt_dims(&r.dims),
            fmt_dims(&r.sketch_dims),
            format!("{:.1}", r.true_ip),
            format!("{:.1}", r.est_ip),
            format!("{:.1}", r.abs_err()),
            format!("{:.1}", r.bound_abs),
            format!("{:.4}", r.rel_err()),
        ]);
    }
    t.print();

    // acceptance asserts — a violated bound fails the bench (and CI)
    let mut headline = f64::INFINITY;
    for r in &mem_rows {
        assert!(
            r.ratio() >= MEM_RATIO_FLOOR,
            "memory ratio {:.1} below floor {MEM_RATIO_FLOOR} for dims {:?}",
            r.ratio(),
            r.dims
        );
        assert!(
            r.hcs_mae <= ERR_SLACK * r.flat_mae + 1e-6,
            "HCS error {:.3} not matched to flat CS error {:.3} (slack {ERR_SLACK})",
            r.hcs_mae,
            r.flat_mae
        );
        headline = headline.min(r.ratio());
    }
    for r in &contract_rows {
        assert!(
            r.abs_err() <= r.bound_abs,
            "CONTRACT error {:.2} exceeds 8*|A||B|/sqrt(prod m) = {:.2} at sketch {:?}",
            r.abs_err(),
            r.bound_abs,
            r.sketch_dims
        );
    }
    println!(
        "\nheadline: HCS uses {:.1}x less memory than flat CS at matched error \
         (floor {MEM_RATIO_FLOOR}x); all CONTRACT errors within the 8/sqrt(prod m) bound",
        headline
    );

    let kernels = kernel_rows();
    let mut t = Table::new(
        "ND fused kernel: scalar walk vs two-phase vectorized",
        &["batch", "scalar items/s", "kernel items/s", "speedup"],
    );
    for r in &kernels {
        t.row(vec![
            r.batch.to_string(),
            format!("{:.0}", r.scalar_per_sec),
            format!("{:.0}", r.kernel_per_sec),
            format!("{:.1}x", r.speedup),
        ]);
    }
    println!();
    t.print();
    if let Some(r) = kernels.iter().find(|r| r.batch == 8192) {
        println!(
            "\nvectorized ND update_batch speedup at batch=8192: {:.1}x over the scalar walk",
            r.speedup
        );
    }

    let json = Json::obj(vec![
        ("bench", Json::Str("tensor".into())),
        ("quick", Json::Bool(quick())),
        ("d", Json::Num(D as f64)),
        ("mem_ratio_floor", Json::Num(MEM_RATIO_FLOOR)),
        ("headline_mem_ratio", Json::Num(headline)),
        (
            "memory",
            Json::Arr(
                mem_rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("dims", Json::arr_usize(&r.dims)),
                            ("sketch_dims", Json::arr_usize(&r.sketch_dims)),
                            ("updates", Json::Num(r.updates as f64)),
                            ("hcs_bytes", Json::Num(r.hcs_bytes)),
                            ("flat_bytes", Json::Num(r.flat_bytes)),
                            ("mem_ratio", Json::Num(r.ratio())),
                            ("hcs_mae", Json::Num(r.hcs_mae)),
                            ("flat_mae", Json::Num(r.flat_mae)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "kernel",
            Json::Arr(
                kernels
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("batch", Json::Num(r.batch as f64)),
                            ("scalar_per_sec", Json::Num(r.scalar_per_sec)),
                            ("kernel_per_sec", Json::Num(r.kernel_per_sec)),
                            ("speedup", Json::Num(r.speedup)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "contract",
            Json::Arr(
                contract_rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("dims", Json::arr_usize(&r.dims)),
                            ("sketch_dims", Json::arr_usize(&r.sketch_dims)),
                            ("norm_a", Json::Num(r.norm_a)),
                            ("norm_b", Json::Num(r.norm_b)),
                            ("true_ip", Json::Num(r.true_ip)),
                            ("est_ip", Json::Num(r.est_ip)),
                            ("abs_err", Json::Num(r.abs_err())),
                            ("rel_err", Json::Num(r.rel_err())),
                            ("bound_abs", Json::Num(r.bound_abs)),
                            ("within_bound", Json::Bool(r.abs_err() <= r.bound_abs)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match std::fs::write(OUT_PATH, json.to_string_pretty()) {
        Ok(()) => println!("wrote {OUT_PATH}"),
        Err(e) => eprintln!("failed to write {OUT_PATH}: {e}"),
    }
}
