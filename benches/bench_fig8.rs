//! `cargo bench` target: Figure 8 (Kron sketch error/time vs ratio).
use hocs::experiments::{run_fig8, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    let (table, rows) = run_fig8(&cfg, 10);
    table.print();
    let mean_speedup: f64 = rows
        .iter()
        .map(|r| r.cts_time.as_secs_f64() / r.mts_time.as_secs_f64())
        .sum::<f64>()
        / rows.len() as f64;
    println!("mean MTS-over-CTS compression speedup: {mean_speedup:.1}x");
}
