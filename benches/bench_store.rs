//! `cargo bench` target: sharded-store throughput/latency sweep.
//!
//! Measures, per shard count K ∈ {1, 2, 4, 8}:
//! - multi-writer update throughput (4 threads hammering one store);
//! - point-query latency p50/p99 (measured per call);
//! - scan-plane throughput: TOPK and HEAVY through the version-stamped
//!   cache vs the full K-way re-merge (`merged_uncached`), plus one
//!   mixed 90/10 read/write row at K = 8;
//!
//! plus one loopback-TCP row (framed protocol + batch updates through
//! `StoreServer`/`StoreClient`), the durable (WAL-on) comparison of
//! per-item commits vs group-commit batches, and the
//! concurrent-single-update-writer sweep with the leader/follower
//! cross-connection group commit on vs off (flush-only and fsync) — the
//! numbers that justify the batched write path and the commit queue —
//! and the observability-overhead pair (span + `rpc_observe` per
//! request, tracing off vs on) that holds the obs plane to its ≤3%
//! contract.
//! Writes everything to `BENCH_store.json` so future PRs have a perf
//! trajectory. Set `HOCS_BENCH_QUICK=1` (CI's `bench-smoke` job) for a
//! seconds-long sweep with the same schema.

use hocs::rng::Pcg64;
use hocs::sketch::stream::StreamSketch;
use hocs::store::{
    DurableOptions, DurableStore, ShardedStore, StoreClient, StoreConfig, StoreServer,
    StoreServerConfig,
};
use hocs::util::bench::Table;
use hocs::util::json::Json;
use std::time::Instant;

const OUT_PATH: &str = "BENCH_store.json";

/// Key universe / sketch geometry for the sweep: 16k×16k keys into
/// 64×64×d counters — big enough that shard routing dominates, small
/// enough that the bench stays seconds-long.
fn bench_cfg(shards: usize) -> StoreConfig {
    StoreConfig { n1: 1 << 14, n2: 1 << 14, m1: 64, m2: 64, d: 5, seed: 42, shards, window: 4 }
}

/// Short-sweep mode for CI smoke runs: same rows, same schema, capped
/// iteration counts.
fn quick() -> bool {
    std::env::var("HOCS_BENCH_QUICK").is_ok()
}

/// Cap `n` in quick mode.
fn scaled(n: usize) -> usize {
    if quick() {
        (n / 10).max(1)
    } else {
        n
    }
}

const WRITER_THREADS: usize = 4;
const CONCURRENT_WRITERS: usize = 8;

fn percentile_us(sorted_ns: &[u64], p: f64) -> f64 {
    assert!(!sorted_ns.is_empty());
    let idx = ((sorted_ns.len() as f64 - 1.0) * p).round() as usize;
    sorted_ns[idx] as f64 / 1e3
}

struct Row {
    label: String,
    shards: usize,
    updates: usize,
    updates_per_sec: f64,
    queries: usize,
    query_p50_us: f64,
    query_p99_us: f64,
}

fn sweep_in_process() -> Vec<Row> {
    let updates_per_thread = scaled(50_000);
    let queries = scaled(5_000);
    let mut rows = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        let cfg = bench_cfg(shards);
        let store = ShardedStore::new(cfg.clone());
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for t in 0..WRITER_THREADS {
                let store = &store;
                let cfg = &cfg;
                scope.spawn(move || {
                    let mut rng = Pcg64::new(1_000 + t as u64);
                    for _ in 0..updates_per_thread {
                        let i = rng.gen_range(cfg.n1 as u64) as usize;
                        let j = rng.gen_range(cfg.n2 as u64) as usize;
                        store.update(i, j, 1.0);
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let updates = WRITER_THREADS * updates_per_thread;

        let mut rng = Pcg64::new(7);
        let mut lat_ns = Vec::with_capacity(queries);
        for _ in 0..queries {
            let i = rng.gen_range(cfg.n1 as u64) as usize;
            let j = rng.gen_range(cfg.n2 as u64) as usize;
            let q0 = Instant::now();
            std::hint::black_box(store.point_query(i, j));
            lat_ns.push(q0.elapsed().as_nanos() as u64);
        }
        lat_ns.sort_unstable();
        rows.push(Row {
            label: format!("in-process K={shards}"),
            shards,
            updates,
            updates_per_sec: updates as f64 / wall,
            queries,
            query_p50_us: percentile_us(&lat_ns, 0.5),
            query_p99_us: percentile_us(&lat_ns, 0.99),
        });
    }
    rows
}

fn tcp_loopback_row() -> Option<Row> {
    let shards = 4;
    let server = match StoreServer::start(StoreServerConfig {
        addr: "127.0.0.1:0".to_string(),
        store: bench_cfg(shards),
        ..Default::default()
    }) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("tcp row skipped: {e}");
            return None;
        }
    };
    let mut client = match StoreClient::connect(server.local_addr()) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("tcp row skipped: {e}");
            server.shutdown();
            return None;
        }
    };
    let n1 = 1u64 << 14;
    let mut rng = Pcg64::new(3);
    let total_updates = scaled(40_000);
    let chunk = 1_000.min(total_updates);
    let t0 = Instant::now();
    let mut sent = 0usize;
    while sent < total_updates {
        let batch: Vec<(u32, u32, f64)> = (0..chunk)
            .map(|_| (rng.gen_range(n1) as u32, rng.gen_range(n1) as u32, 1.0))
            .collect();
        if let Err(e) = client.update_batch(&batch) {
            eprintln!("tcp row aborted: {e}");
            server.shutdown();
            return None;
        }
        sent += chunk;
    }
    let wall = t0.elapsed().as_secs_f64();
    let queries = scaled(2_000);
    let mut lat_ns = Vec::with_capacity(queries);
    for _ in 0..queries {
        let (i, j) = (rng.gen_range(n1) as usize, rng.gen_range(n1) as usize);
        let q0 = Instant::now();
        let _ = std::hint::black_box(client.query(i, j));
        lat_ns.push(q0.elapsed().as_nanos() as u64);
    }
    lat_ns.sort_unstable();
    server.shutdown();
    Some(Row {
        label: format!("tcp-loopback K={shards}"),
        shards,
        updates: sent,
        updates_per_sec: sent as f64 / wall,
        queries,
        query_p50_us: percentile_us(&lat_ns, 0.5),
        query_p99_us: percentile_us(&lat_ns, 0.99),
    })
}

/// Durable-path comparison: the same update volume through per-item
/// WAL commits (one frame + flush each) and through group-commit
/// batches (one frame + flush per batch, shard-grouped apply). The
/// ratio is the group-commit win.
fn durable_rows() -> Vec<Row> {
    let shards = 4;
    let base = std::env::temp_dir().join(format!("hocs_bench_store_wal_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let n1 = 1u64 << 14;
    let total = scaled(20_000);
    let queries = scaled(2_000);
    let mut rows = Vec::new();

    let mut run = |label: String, batch: usize| {
        let dir = base.join(label.replace(' ', "_").replace('=', "_"));
        let store = match DurableStore::open(&dir, bench_cfg(shards)) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("durable row {label:?} skipped: {e}");
                return;
            }
        };
        let mut rng = Pcg64::new(9);
        let t0 = Instant::now();
        if batch <= 1 {
            for _ in 0..total {
                store
                    .update(rng.gen_range(n1) as usize, rng.gen_range(n1) as usize, 1.0)
                    .expect("durable update");
            }
        } else {
            let mut sent = 0usize;
            while sent < total {
                let n = batch.min(total - sent);
                let items: Vec<(usize, usize, f64)> = (0..n)
                    .map(|_| {
                        (rng.gen_range(n1) as usize, rng.gen_range(n1) as usize, 1.0)
                    })
                    .collect();
                store.update_batch(&items).expect("durable batch");
                sent += n;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let mut lat_ns = Vec::with_capacity(queries);
        for _ in 0..queries {
            let (i, j) = (rng.gen_range(n1) as usize, rng.gen_range(n1) as usize);
            let q0 = Instant::now();
            std::hint::black_box(store.point_query(i, j));
            lat_ns.push(q0.elapsed().as_nanos() as u64);
        }
        lat_ns.sort_unstable();
        rows.push(Row {
            label,
            shards,
            updates: total,
            updates_per_sec: total as f64 / wall,
            queries,
            query_p50_us: percentile_us(&lat_ns, 0.5),
            query_p99_us: percentile_us(&lat_ns, 0.99),
        });
    };

    run("durable per-item".to_string(), 1);
    for batch in [256usize, 1024] {
        run(format!("durable batch={batch}"), batch);
    }
    let _ = std::fs::remove_dir_all(&base);
    rows
}

// ---------- scan plane: cached vs uncached ----------

struct ScanRow {
    kind: String,
    shards: usize,
    cached_per_sec: f64,
    uncached_per_sec: f64,
    speedup: f64,
}

/// Smaller universe than the update sweep: a scan costs O(d·m1·n2) per
/// re-scan, and the interesting ratio is cache hit vs full re-merge.
fn scan_cfg(shards: usize) -> StoreConfig {
    StoreConfig { n1: 1 << 12, n2: 1 << 12, m1: 64, m2: 64, d: 5, seed: 42, shards, window: 4 }
}

/// Skewed preload: a handful of heavy keys over uniform noise, the
/// traffic shape the marginal-pruned scans are built for.
fn preload_scan_store(store: &ShardedStore, cfg: &StoreConfig, total: usize) {
    let mut rng = Pcg64::new(11);
    let mut fed = 0usize;
    let mut batch = Vec::with_capacity(1024);
    while fed < total {
        batch.clear();
        let n = 1024.min(total - fed);
        for _ in 0..n {
            let (i, j) = if rng.uniform() < 0.2 {
                ((rng.gen_range(16) as usize * 37) % cfg.n1, 7usize)
            } else {
                (rng.gen_range(cfg.n1 as u64) as usize, rng.gen_range(cfg.n2 as u64) as usize)
            };
            batch.push((i, j, 1.0));
        }
        store.update_batch(&batch);
        fed += n;
    }
}

fn scan_rows() -> Vec<ScanRow> {
    let preload = scaled(60_000);
    let uncached_iters = scaled(60);
    let cached_iters = scaled(600);
    let k = 32usize;
    let threshold = 40.0f64;
    let mut rows = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        let cfg = scan_cfg(shards);
        let store = ShardedStore::new(cfg.clone());
        preload_scan_store(&store, &cfg, preload);

        // TOPK: full re-merge + scan per call vs the cached scan plane
        let t0 = Instant::now();
        for _ in 0..uncached_iters {
            std::hint::black_box(store.merged_uncached().top_k(k));
        }
        let un_topk = uncached_iters as f64 / t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        for _ in 0..cached_iters {
            std::hint::black_box(store.top_k(k));
        }
        let c_topk = cached_iters as f64 / t0.elapsed().as_secs_f64();
        rows.push(ScanRow {
            kind: "TOPK".to_string(),
            shards,
            cached_per_sec: c_topk,
            uncached_per_sec: un_topk,
            speedup: c_topk / un_topk,
        });

        // HEAVY, same comparison
        let t0 = Instant::now();
        for _ in 0..uncached_iters {
            std::hint::black_box(store.merged_uncached().heavy_hitters(threshold));
        }
        let un_heavy = uncached_iters as f64 / t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        for _ in 0..cached_iters {
            std::hint::black_box(store.heavy_hitters(threshold));
        }
        let c_heavy = cached_iters as f64 / t0.elapsed().as_secs_f64();
        rows.push(ScanRow {
            kind: "HEAVY".to_string(),
            shards,
            cached_per_sec: c_heavy,
            uncached_per_sec: un_heavy,
            speedup: c_heavy / un_heavy,
        });
    }

    // mixed 90/10 read/write at K = 8: every write invalidates the
    // stamp, so this measures the incremental-refresh + re-scan cost,
    // not just pure cache hits
    let shards = 8;
    let cfg = scan_cfg(shards);
    let store = ShardedStore::new(cfg.clone());
    preload_scan_store(&store, &cfg, preload);
    let ops = scaled(1_000);
    let mut rng = Pcg64::new(13);
    let t0 = Instant::now();
    for op in 0..ops {
        if op % 10 == 9 {
            let (i, j) =
                (rng.gen_range(cfg.n1 as u64) as usize, rng.gen_range(cfg.n2 as u64) as usize);
            store.update(i, j, 1.0);
        } else {
            std::hint::black_box(store.top_k(k));
        }
    }
    let mixed_cached = ops as f64 / t0.elapsed().as_secs_f64();
    let mut rng = Pcg64::new(13);
    let t0 = Instant::now();
    for op in 0..ops {
        if op % 10 == 9 {
            let (i, j) =
                (rng.gen_range(cfg.n1 as u64) as usize, rng.gen_range(cfg.n2 as u64) as usize);
            store.update(i, j, 1.0);
        } else {
            std::hint::black_box(store.merged_uncached().top_k(k));
        }
    }
    let mixed_uncached = ops as f64 / t0.elapsed().as_secs_f64();
    rows.push(ScanRow {
        kind: "MIXED 90/10".to_string(),
        shards,
        cached_per_sec: mixed_cached,
        uncached_per_sec: mixed_uncached,
        speedup: mixed_cached / mixed_uncached,
    });
    rows
}

// ---------- fused kernel: scalar walk vs two-phase vectorized ----------

struct KernelRow {
    op: String,
    batch: usize,
    scalar_per_sec: f64,
    kernel_per_sec: f64,
    speedup: f64,
}

/// Scalar oracle vs the two-phase kernel on the same batch, for the
/// plain fused walk and the width-3 fan-out. `HOCS_KERNEL` still
/// applies, so the CI scalar-forced leg reports a ~1x speedup — the
/// schema is the same either way.
fn kernel_rows() -> Vec<KernelRow> {
    let (n1, n2, m1, m2, d) = (1usize << 14, 1 << 14, 64, 64, 5);
    let mut rows = Vec::new();
    for batch in [64usize, 1024, 8192] {
        let reps = scaled((2_000_000 / batch).max(1));
        let mut rng = Pcg64::new(17);
        let items: Vec<(usize, usize, f64)> = (0..batch)
            .map(|_| {
                (rng.gen_range(n1 as u64) as usize, rng.gen_range(n2 as u64) as usize, 1.0)
            })
            .collect();

        let mut sk = StreamSketch::new(n1, n2, m1, m2, d, 42);
        let t0 = Instant::now();
        for _ in 0..reps {
            sk.update_batch_scalar(&items);
        }
        let scalar = (reps * batch) as f64 / t0.elapsed().as_secs_f64();
        std::hint::black_box(sk.query(1, 1));
        let mut sk = StreamSketch::new(n1, n2, m1, m2, d, 42);
        let t0 = Instant::now();
        for _ in 0..reps {
            sk.update_batch(&items);
        }
        let kernel = (reps * batch) as f64 / t0.elapsed().as_secs_f64();
        std::hint::black_box(sk.query(1, 1));
        rows.push(KernelRow {
            op: "update_batch".to_string(),
            batch,
            scalar_per_sec: scalar,
            kernel_per_sec: kernel,
            speedup: kernel / scalar,
        });

        let fan_reps = (reps / 2).max(1);
        let mk = || {
            (0..3).map(|_| StreamSketch::new(n1, n2, m1, m2, d, 42)).collect::<Vec<_>>()
        };
        let mut fans = mk();
        let t0 = Instant::now();
        for _ in 0..fan_reps {
            let mut targets: Vec<&mut StreamSketch> = fans.iter_mut().collect();
            StreamSketch::update_batch_fanout_scalar(&mut targets, &items);
        }
        let scalar = (fan_reps * batch) as f64 / t0.elapsed().as_secs_f64();
        std::hint::black_box(fans[0].query(1, 1));
        let mut fans = mk();
        let t0 = Instant::now();
        for _ in 0..fan_reps {
            let mut targets: Vec<&mut StreamSketch> = fans.iter_mut().collect();
            StreamSketch::update_batch_fanout(&mut targets, &items);
        }
        let kernel = (fan_reps * batch) as f64 / t0.elapsed().as_secs_f64();
        std::hint::black_box(fans[0].query(1, 1));
        rows.push(KernelRow {
            op: "update_batch_fanout x3".to_string(),
            batch,
            scalar_per_sec: scalar,
            kernel_per_sec: kernel,
            speedup: kernel / scalar,
        });
    }
    rows
}

// ---------- observability: instrumentation overhead ----------

struct ObsRow {
    mode: String,
    updates_per_sec: f64,
    overhead_pct: f64,
}

/// The obs contract priced: per "request" the server pays one span
/// guard plus one `rpc_observe` around the real work (here a
/// server-sized `update_batch` chunk). Tracing off is the shipping
/// default; tracing on must stay within ~3% of it. Best-of-3 per mode
/// so a CI scheduler hiccup can't fake an overhead regression.
fn obs_rows() -> Vec<ObsRow> {
    let (n1, n2, m1, m2, d) = (1usize << 14, 1 << 14, 64, 64, 5);
    let batch = 4096usize;
    let reps = scaled(2_000);
    let mut rng = Pcg64::new(23);
    let items: Vec<(usize, usize, f64)> = (0..batch)
        .map(|_| (rng.gen_range(n1 as u64) as usize, rng.gen_range(n2 as u64) as usize, 1.0))
        .collect();

    let run = |traced: bool| -> f64 {
        hocs::obs::trace::set_enabled(traced);
        let mut best = 0.0f64;
        for _ in 0..3 {
            let mut sk = StreamSketch::new(n1, n2, m1, m2, d, 42);
            let t0 = Instant::now();
            for _ in 0..reps {
                let r0 = Instant::now();
                {
                    let _span = hocs::obs::trace::span("bench.update_batch");
                    sk.update_batch(&items);
                }
                let us = r0.elapsed().as_micros() as u64;
                hocs::obs::global().rpc_observe(2, us, true);
            }
            let per_sec = (reps * batch) as f64 / t0.elapsed().as_secs_f64();
            std::hint::black_box(sk.query(1, 1));
            best = best.max(per_sec);
        }
        hocs::obs::trace::set_enabled(false);
        best
    };

    let off = run(false);
    let on = run(true);
    vec![
        ObsRow { mode: "trace_off".to_string(), updates_per_sec: off, overhead_pct: 0.0 },
        ObsRow {
            mode: "trace_on".to_string(),
            updates_per_sec: on,
            overhead_pct: (off - on) / off * 100.0,
        },
    ]
}

// ---------- concurrent un-batched writers: group commit on/off ----------

struct ConcRow {
    label: String,
    writers: usize,
    fsync: bool,
    group: bool,
    updates: usize,
    updates_per_sec: f64,
}

fn durable_concurrent_rows() -> Vec<ConcRow> {
    let shards = 4;
    let writers = CONCURRENT_WRITERS;
    let base = std::env::temp_dir().join(format!("hocs_bench_store_cc_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let n1 = 1u64 << 14;
    let mut rows = Vec::new();

    let mut run = |label: String, fsync: bool, group: bool, per_writer: usize| {
        let dir = base.join(label.replace(' ', "_").replace('=', "_"));
        let store = match DurableStore::open_opts(
            &dir,
            bench_cfg(shards),
            DurableOptions { fsync, group_commit: group },
        ) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("concurrent durable row {label:?} skipped: {e}");
                return;
            }
        };
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for t in 0..writers {
                let store = &store;
                scope.spawn(move || {
                    let mut rng = Pcg64::new(40 + t as u64);
                    for _ in 0..per_writer {
                        store
                            .update(
                                rng.gen_range(n1) as usize,
                                rng.gen_range(n1) as usize,
                                1.0,
                            )
                            .expect("durable update");
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let updates = writers * per_writer;
        rows.push(ConcRow {
            label,
            writers,
            fsync,
            group,
            updates,
            updates_per_sec: updates as f64 / wall,
        });
    };

    // flush-only (process-crash durability): the commit queue coalesces
    // write syscalls and shrinks mutex hold times
    let flush_per_writer = scaled(6_000);
    run("cc flush group=off".to_string(), false, false, flush_per_writer);
    run("cc flush group=on".to_string(), false, true, flush_per_writer);
    // fsync (power-loss durability): one sync_data per *group* instead
    // of per record — where leader/follower group commit earns its keep
    let sync_per_writer = scaled(400);
    run("cc fsync group=off".to_string(), true, false, sync_per_writer);
    run("cc fsync group=on".to_string(), true, true, sync_per_writer);

    let _ = std::fs::remove_dir_all(&base);
    rows
}

fn main() {
    if quick() {
        println!("HOCS_BENCH_QUICK set: short sweep (CI smoke), same schema\n");
    }
    let mut rows = sweep_in_process();
    if let Some(tcp) = tcp_loopback_row() {
        rows.push(tcp);
    }
    rows.extend(durable_rows());

    let mut table = Table::new(
        "store throughput/latency vs shard count",
        &["path", "shards", "updates/s", "query p50", "query p99"],
    );
    for r in &rows {
        table.row(vec![
            r.label.clone(),
            r.shards.to_string(),
            format!("{:.0}", r.updates_per_sec),
            format!("{:.1} µs", r.query_p50_us),
            format!("{:.1} µs", r.query_p99_us),
        ]);
    }
    table.print();

    let per_item = rows.iter().find(|r| r.label == "durable per-item");
    let batched = rows.iter().find(|r| r.label == "durable batch=256");
    if let (Some(p), Some(b)) = (per_item, batched) {
        println!(
            "\ngroup-commit speedup at batch=256: {:.1}x over per-item durable commits",
            b.updates_per_sec / p.updates_per_sec
        );
    }

    let scans = scan_rows();
    let mut scan_table = Table::new(
        "scan plane: version-stamped cache vs full K-way re-merge",
        &["scan", "shards", "cached/s", "uncached/s", "speedup"],
    );
    for r in &scans {
        scan_table.row(vec![
            r.kind.clone(),
            r.shards.to_string(),
            format!("{:.0}", r.cached_per_sec),
            format!("{:.0}", r.uncached_per_sec),
            format!("{:.1}x", r.speedup),
        ]);
    }
    println!();
    scan_table.print();
    if let Some(r) = scans.iter().find(|r| r.kind == "TOPK" && r.shards == 8) {
        println!(
            "\ncached TOPK speedup at K=8: {:.1}x over per-call re-merge (target >= 5x)",
            r.speedup
        );
    }

    let conc = durable_concurrent_rows();
    let mut conc_table = Table::new(
        "concurrent single-update writers: leader/follower group commit",
        &["path", "writers", "updates", "updates/s"],
    );
    for r in &conc {
        conc_table.row(vec![
            r.label.clone(),
            r.writers.to_string(),
            r.updates.to_string(),
            format!("{:.0}", r.updates_per_sec),
        ]);
    }
    println!();
    conc_table.print();
    let speedup = |on: &str, off: &str| -> Option<f64> {
        let a = conc.iter().find(|r| r.label == on)?;
        let b = conc.iter().find(|r| r.label == off)?;
        Some(a.updates_per_sec / b.updates_per_sec)
    };
    if let Some(s) = speedup("cc flush group=on", "cc flush group=off") {
        println!(
            "\ncross-connection group commit speedup ({CONCURRENT_WRITERS} writers, flush): \
             {s:.1}x over per-record commits"
        );
    }
    if let Some(s) = speedup("cc fsync group=on", "cc fsync group=off") {
        println!(
            "cross-connection group commit speedup ({CONCURRENT_WRITERS} writers, fsync): \
             {s:.1}x over per-record syncs (target >= 3x)"
        );
    }

    let kernels = kernel_rows();
    let mut kernel_table = Table::new(
        "fused kernel: scalar walk vs two-phase vectorized",
        &["op", "batch", "scalar items/s", "kernel items/s", "speedup"],
    );
    for r in &kernels {
        kernel_table.row(vec![
            r.op.clone(),
            r.batch.to_string(),
            format!("{:.0}", r.scalar_per_sec),
            format!("{:.0}", r.kernel_per_sec),
            format!("{:.1}x", r.speedup),
        ]);
    }
    println!();
    kernel_table.print();
    if let Some(r) = kernels.iter().find(|r| r.op == "update_batch" && r.batch == 8192) {
        println!(
            "\nvectorized update_batch speedup at batch=8192: {:.1}x over the scalar walk \
             (target >= 4x)",
            r.speedup
        );
    }

    let obs = obs_rows();
    let mut obs_table = Table::new(
        "observability: span + rpc_observe per batched request",
        &["mode", "updates/s", "overhead"],
    );
    for r in &obs {
        obs_table.row(vec![
            r.mode.clone(),
            format!("{:.0}", r.updates_per_sec),
            format!("{:.2}%", r.overhead_pct),
        ]);
    }
    println!();
    obs_table.print();
    if let Some(r) = obs.iter().find(|r| r.mode == "trace_on") {
        println!(
            "\ntracing-on instrumentation overhead: {:.2}% (target <= 3%)",
            r.overhead_pct
        );
    }

    let json = Json::obj(vec![
        (
            "store",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("path", Json::Str(r.label.clone())),
                            ("shards", Json::Num(r.shards as f64)),
                            ("updates", Json::Num(r.updates as f64)),
                            ("updates_per_sec", Json::Num(r.updates_per_sec)),
                            ("queries", Json::Num(r.queries as f64)),
                            ("query_p50_us", Json::Num(r.query_p50_us)),
                            ("query_p99_us", Json::Num(r.query_p99_us)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "scan",
            Json::Arr(
                scans
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("kind", Json::Str(r.kind.clone())),
                            ("shards", Json::Num(r.shards as f64)),
                            ("cached_per_sec", Json::Num(r.cached_per_sec)),
                            ("uncached_per_sec", Json::Num(r.uncached_per_sec)),
                            ("speedup", Json::Num(r.speedup)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "kernel",
            Json::Arr(
                kernels
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("op", Json::Str(r.op.clone())),
                            ("batch", Json::Num(r.batch as f64)),
                            ("scalar_per_sec", Json::Num(r.scalar_per_sec)),
                            ("kernel_per_sec", Json::Num(r.kernel_per_sec)),
                            ("speedup", Json::Num(r.speedup)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "obs",
            Json::Arr(
                obs.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("mode", Json::Str(r.mode.clone())),
                            ("updates_per_sec", Json::Num(r.updates_per_sec)),
                            ("overhead_pct", Json::Num(r.overhead_pct)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "durable_concurrent",
            Json::Arr(
                conc.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("path", Json::Str(r.label.clone())),
                            ("writers", Json::Num(r.writers as f64)),
                            ("fsync", Json::Bool(r.fsync)),
                            ("group_commit", Json::Bool(r.group)),
                            ("updates", Json::Num(r.updates as f64)),
                            ("updates_per_sec", Json::Num(r.updates_per_sec)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match std::fs::write(OUT_PATH, json.to_string_pretty()) {
        Ok(()) => println!("\nwrote {OUT_PATH}"),
        Err(e) => eprintln!("could not write {OUT_PATH}: {e}"),
    }
}
