//! `cargo bench` target: sharded-store throughput/latency sweep.
//!
//! Measures, per shard count K ∈ {1, 2, 4, 8}:
//! - multi-writer update throughput (4 threads hammering one store);
//! - point-query latency p50/p99 (measured per call);
//!
//! plus one loopback-TCP row (framed protocol + batch updates through
//! `StoreServer`/`StoreClient`) and a durable (WAL-on) comparison of
//! per-item commits vs group-commit batches — the number that justifies
//! the batched write path. Writes everything to `BENCH_store.json` so
//! future PRs have a perf trajectory.

use hocs::rng::Pcg64;
use hocs::store::{
    DurableStore, ShardedStore, StoreClient, StoreConfig, StoreServer, StoreServerConfig,
};
use hocs::util::bench::Table;
use hocs::util::json::Json;
use std::time::Instant;

const OUT_PATH: &str = "BENCH_store.json";

/// Key universe / sketch geometry for the sweep: 16k×16k keys into
/// 64×64×d counters — big enough that shard routing dominates, small
/// enough that the bench stays seconds-long.
fn bench_cfg(shards: usize) -> StoreConfig {
    StoreConfig { n1: 1 << 14, n2: 1 << 14, m1: 64, m2: 64, d: 5, seed: 42, shards, window: 4 }
}

const WRITER_THREADS: usize = 4;
const UPDATES_PER_THREAD: usize = 50_000;
const QUERIES: usize = 5_000;

fn percentile_us(sorted_ns: &[u64], p: f64) -> f64 {
    assert!(!sorted_ns.is_empty());
    let idx = ((sorted_ns.len() as f64 - 1.0) * p).round() as usize;
    sorted_ns[idx] as f64 / 1e3
}

struct Row {
    label: String,
    shards: usize,
    updates: usize,
    updates_per_sec: f64,
    queries: usize,
    query_p50_us: f64,
    query_p99_us: f64,
}

fn sweep_in_process() -> Vec<Row> {
    let mut rows = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        let cfg = bench_cfg(shards);
        let store = ShardedStore::new(cfg.clone());
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for t in 0..WRITER_THREADS {
                let store = &store;
                let cfg = &cfg;
                scope.spawn(move || {
                    let mut rng = Pcg64::new(1_000 + t as u64);
                    for _ in 0..UPDATES_PER_THREAD {
                        let i = rng.gen_range(cfg.n1 as u64) as usize;
                        let j = rng.gen_range(cfg.n2 as u64) as usize;
                        store.update(i, j, 1.0);
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let updates = WRITER_THREADS * UPDATES_PER_THREAD;

        let mut rng = Pcg64::new(7);
        let mut lat_ns = Vec::with_capacity(QUERIES);
        for _ in 0..QUERIES {
            let i = rng.gen_range(cfg.n1 as u64) as usize;
            let j = rng.gen_range(cfg.n2 as u64) as usize;
            let q0 = Instant::now();
            std::hint::black_box(store.point_query(i, j));
            lat_ns.push(q0.elapsed().as_nanos() as u64);
        }
        lat_ns.sort_unstable();
        rows.push(Row {
            label: format!("in-process K={shards}"),
            shards,
            updates,
            updates_per_sec: updates as f64 / wall,
            queries: QUERIES,
            query_p50_us: percentile_us(&lat_ns, 0.5),
            query_p99_us: percentile_us(&lat_ns, 0.99),
        });
    }
    rows
}

fn tcp_loopback_row() -> Option<Row> {
    let shards = 4;
    let server = match StoreServer::start(StoreServerConfig {
        addr: "127.0.0.1:0".to_string(),
        store: bench_cfg(shards),
        ..Default::default()
    }) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("tcp row skipped: {e}");
            return None;
        }
    };
    let mut client = match StoreClient::connect(server.local_addr()) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("tcp row skipped: {e}");
            server.shutdown();
            return None;
        }
    };
    let n1 = 1u64 << 14;
    let mut rng = Pcg64::new(3);
    let total_updates = 40_000;
    let chunk = 1_000;
    let t0 = Instant::now();
    let mut sent = 0usize;
    while sent < total_updates {
        let batch: Vec<(u32, u32, f64)> = (0..chunk)
            .map(|_| (rng.gen_range(n1) as u32, rng.gen_range(n1) as u32, 1.0))
            .collect();
        if let Err(e) = client.update_batch(&batch) {
            eprintln!("tcp row aborted: {e}");
            server.shutdown();
            return None;
        }
        sent += chunk;
    }
    let wall = t0.elapsed().as_secs_f64();
    let queries = 2_000;
    let mut lat_ns = Vec::with_capacity(queries);
    for _ in 0..queries {
        let (i, j) = (rng.gen_range(n1) as usize, rng.gen_range(n1) as usize);
        let q0 = Instant::now();
        let _ = std::hint::black_box(client.query(i, j));
        lat_ns.push(q0.elapsed().as_nanos() as u64);
    }
    lat_ns.sort_unstable();
    server.shutdown();
    Some(Row {
        label: format!("tcp-loopback K={shards}"),
        shards,
        updates: sent,
        updates_per_sec: sent as f64 / wall,
        queries,
        query_p50_us: percentile_us(&lat_ns, 0.5),
        query_p99_us: percentile_us(&lat_ns, 0.99),
    })
}

/// Durable-path comparison: the same update volume through per-item
/// WAL commits (one frame + flush each) and through group-commit
/// batches (one frame + flush per batch, shard-grouped apply). The
/// ratio is the group-commit win.
fn durable_rows() -> Vec<Row> {
    let shards = 4;
    let base = std::env::temp_dir().join(format!("hocs_bench_store_wal_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let n1 = 1u64 << 14;
    let total = 20_000usize;
    let mut rows = Vec::new();

    let mut run = |label: String, batch: usize| {
        let dir = base.join(label.replace(' ', "_").replace('=', "_"));
        let store = match DurableStore::open(&dir, bench_cfg(shards)) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("durable row {label:?} skipped: {e}");
                return;
            }
        };
        let mut rng = Pcg64::new(9);
        let t0 = Instant::now();
        if batch <= 1 {
            for _ in 0..total {
                store
                    .update(rng.gen_range(n1) as usize, rng.gen_range(n1) as usize, 1.0)
                    .expect("durable update");
            }
        } else {
            let mut sent = 0usize;
            while sent < total {
                let n = batch.min(total - sent);
                let items: Vec<(usize, usize, f64)> = (0..n)
                    .map(|_| {
                        (rng.gen_range(n1) as usize, rng.gen_range(n1) as usize, 1.0)
                    })
                    .collect();
                store.update_batch(&items).expect("durable batch");
                sent += n;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let queries = 2_000;
        let mut lat_ns = Vec::with_capacity(queries);
        for _ in 0..queries {
            let (i, j) = (rng.gen_range(n1) as usize, rng.gen_range(n1) as usize);
            let q0 = Instant::now();
            std::hint::black_box(store.point_query(i, j));
            lat_ns.push(q0.elapsed().as_nanos() as u64);
        }
        lat_ns.sort_unstable();
        rows.push(Row {
            label,
            shards,
            updates: total,
            updates_per_sec: total as f64 / wall,
            queries,
            query_p50_us: percentile_us(&lat_ns, 0.5),
            query_p99_us: percentile_us(&lat_ns, 0.99),
        });
    };

    run("durable per-item".to_string(), 1);
    for batch in [256usize, 1024] {
        run(format!("durable batch={batch}"), batch);
    }
    let _ = std::fs::remove_dir_all(&base);
    rows
}

fn main() {
    let mut rows = sweep_in_process();
    if let Some(tcp) = tcp_loopback_row() {
        rows.push(tcp);
    }
    rows.extend(durable_rows());

    let mut table = Table::new(
        "store throughput/latency vs shard count",
        &["path", "shards", "updates/s", "query p50", "query p99"],
    );
    for r in &rows {
        table.row(vec![
            r.label.clone(),
            r.shards.to_string(),
            format!("{:.0}", r.updates_per_sec),
            format!("{:.1} µs", r.query_p50_us),
            format!("{:.1} µs", r.query_p99_us),
        ]);
    }
    table.print();

    let per_item = rows.iter().find(|r| r.label == "durable per-item");
    let batched = rows.iter().find(|r| r.label == "durable batch=256");
    if let (Some(p), Some(b)) = (per_item, batched) {
        println!(
            "\ngroup-commit speedup at batch=256: {:.1}x over per-item durable commits",
            b.updates_per_sec / p.updates_per_sec
        );
    }

    let json = Json::obj(vec![(
        "store",
        Json::Arr(
            rows.iter()
                .map(|r| {
                    Json::obj(vec![
                        ("path", Json::Str(r.label.clone())),
                        ("shards", Json::Num(r.shards as f64)),
                        ("updates", Json::Num(r.updates as f64)),
                        ("updates_per_sec", Json::Num(r.updates_per_sec)),
                        ("queries", Json::Num(r.queries as f64)),
                        ("query_p50_us", Json::Num(r.query_p50_us)),
                        ("query_p99_us", Json::Num(r.query_p99_us)),
                    ])
                })
                .collect(),
        ),
    )]);
    match std::fs::write(OUT_PATH, json.to_string_pretty()) {
        Ok(()) => println!("\nwrote {OUT_PATH}"),
        Err(e) => eprintln!("could not write {OUT_PATH}: {e}"),
    }
}
