//! `cargo bench` target: anti-entropy replication — staleness vs
//! bandwidth.
//!
//! Boots two loopback store nodes (a writer W whose only peer is a
//! replica R) per row and sweeps sync interval × write rate, once with
//! delta shipping (the replicator's default: sparse per-peer cursor
//! deltas, full ships only on first contact) and once with
//! `full_ship_every = 1` (every sync ships the dense full origin state
//! — the "ship `merged()` images" baseline the ROADMAP's replication
//! item started from). Per row it reports:
//!
//! - **staleness** — replica-vs-writer point-query error over time: a
//!   tracked heavy key is hammered at a known share of the write rate
//!   and both nodes are polled concurrently; the |W − R| samples
//!   (mean / p95 / max) are the replica's lag in key mass;
//! - **bytes shipped** — from the writer's STATS replication counters
//!   (acknowledged frame payload bytes), plus ships and full ships.
//!
//! The delta-vs-full bytes ratio at the first (shortest-interval)
//! config is the headline number: steady-state delta shipping must
//! move ≥ 5× fewer bytes than full-state shipping. Long intervals at
//! high write rates saturate the delta (the sparse encoding
//! auto-falls-back to dense once most buckets are touched), which the
//! sweep shows honestly — that corner is *why* the full-ship fallback
//! is acceptable at all.
//!
//! Writes everything to `BENCH_replica.json`. `HOCS_BENCH_QUICK=1`
//! (CI's `replica-smoke` job) runs a seconds-long sweep with the same
//! schema.

use hocs::rng::Pcg64;
use hocs::store::{StoreClient, StoreConfig, StoreServer, StoreServerConfig};
use hocs::util::bench::Table;
use hocs::util::json::Json;
use std::time::{Duration, Instant};

const OUT_PATH: &str = "BENCH_replica.json";

fn quick() -> bool {
    std::env::var("HOCS_BENCH_QUICK").is_ok()
}

/// Same sketch geometry on both nodes (the mergeability contract).
/// 64×64×5 counters make a dense full ship ~160 KB — the baseline the
/// sparse deltas are measured against.
fn bench_cfg() -> StoreConfig {
    StoreConfig { n1: 1 << 12, n2: 1 << 12, m1: 64, m2: 64, d: 5, seed: 42, shards: 4, window: 4 }
}

struct Row {
    sync_interval_ms: u64,
    write_rate: usize,
    mode: &'static str,
    ships: u64,
    full_ships: u64,
    bytes_shipped: u64,
    staleness_mean: f64,
    staleness_p95: f64,
    staleness_max: f64,
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx]
}

/// One writer→replica run; `None` when loopback networking is
/// unavailable (the row is skipped, mirroring bench_store's TCP row).
fn run_row(sync_interval_ms: u64, write_rate: usize, full_mode: bool, secs: f64) -> Option<Row> {
    let cfg = bench_cfg();
    let replica = match StoreServer::start(StoreServerConfig {
        addr: "127.0.0.1:0".to_string(),
        store: cfg.clone(),
        ..Default::default()
    }) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("replica row skipped: {e}");
            return None;
        }
    };
    let writer_srv = match StoreServer::start(StoreServerConfig {
        addr: "127.0.0.1:0".to_string(),
        store: cfg.clone(),
        peers: vec![replica.local_addr().to_string()],
        sync_interval_ms,
        full_ship_every: u64::from(full_mode),
        ..Default::default()
    }) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("replica row skipped: {e}");
            replica.shutdown();
            return None;
        }
    };
    let connect = |addr| StoreClient::connect(addr).ok();
    let (Some(mut feed), Some(mut w_probe), Some(mut r_probe)) = (
        connect(writer_srv.local_addr()),
        connect(writer_srv.local_addr()),
        connect(replica.local_addr()),
    ) else {
        eprintln!("replica row skipped: cannot connect");
        writer_srv.shutdown();
        replica.shutdown();
        return None;
    };

    // the tracked key takes a fixed ~30% of the write rate, so its true
    // mass grows at a known pace and |W − R| is pure replication lag
    let tracked = (3usize, 7usize);
    let tick = Duration::from_millis(10);
    let per_tick = (write_rate / 100).max(1);
    let mut rng = Pcg64::new(11);
    let mut batch: Vec<(u32, u32, f64)> = Vec::with_capacity(per_tick);
    let mut errs = Vec::new();
    let t_end = Instant::now() + Duration::from_secs_f64(secs);
    while Instant::now() < t_end {
        let t0 = Instant::now();
        batch.clear();
        for k in 0..per_tick {
            if k * 10 < per_tick * 3 {
                batch.push((tracked.0 as u32, tracked.1 as u32, 1.0));
            } else {
                batch.push((
                    rng.gen_range(cfg.n1 as u64) as u32,
                    rng.gen_range(cfg.n2 as u64) as u32,
                    1.0,
                ));
            }
        }
        if feed.update_batch(&batch).is_err() {
            eprintln!("replica row aborted: writer gone");
            break;
        }
        let (w_est, r_est) = match (
            w_probe.query(tracked.0, tracked.1),
            r_probe.query(tracked.0, tracked.1),
        ) {
            (Ok(a), Ok(b)) => (a, b),
            _ => break,
        };
        errs.push((w_est - r_est).abs());
        let spent = t0.elapsed();
        if spent < tick {
            std::thread::sleep(tick - spent);
        }
    }
    let repl = match w_probe.stats_full() {
        Ok((_, Some(r))) => r,
        _ => {
            eprintln!("replica row aborted: no replication stats");
            writer_srv.shutdown();
            replica.shutdown();
            return None;
        }
    };
    writer_srv.shutdown();
    replica.shutdown();
    errs.sort_by(|a, b| a.partial_cmp(b).expect("finite staleness samples"));
    let mean = errs.iter().sum::<f64>() / errs.len().max(1) as f64;
    Some(Row {
        sync_interval_ms,
        write_rate,
        mode: if full_mode { "full" } else { "delta" },
        ships: repl.ships,
        full_ships: repl.full_ships,
        bytes_shipped: repl.bytes_shipped,
        staleness_mean: mean,
        staleness_p95: percentile(&errs, 0.95),
        staleness_max: percentile(&errs, 1.0),
    })
}

fn main() {
    if quick() {
        println!("HOCS_BENCH_QUICK set: short sweep (CI smoke), same schema\n");
    }
    let secs = if quick() { 0.8 } else { 1.5 };
    let intervals: &[u64] = if quick() { &[40] } else { &[10, 50, 200] };
    let rates: &[usize] = if quick() { &[2_500] } else { &[5_000, 20_000] };

    let mut rows = Vec::new();
    for &interval in intervals {
        for &rate in rates {
            for full_mode in [false, true] {
                if let Some(row) = run_row(interval, rate, full_mode, secs) {
                    rows.push(row);
                }
            }
        }
    }

    let mut table = Table::new(
        "replication: staleness vs bytes shipped (writer -> replica)",
        &["mode", "sync ms", "rate/s", "ships", "full", "bytes", "stale mean", "p95", "max"],
    );
    for r in &rows {
        table.row(vec![
            r.mode.to_string(),
            r.sync_interval_ms.to_string(),
            r.write_rate.to_string(),
            r.ships.to_string(),
            r.full_ships.to_string(),
            r.bytes_shipped.to_string(),
            format!("{:.1}", r.staleness_mean),
            format!("{:.1}", r.staleness_p95),
            format!("{:.1}", r.staleness_max),
        ]);
    }
    table.print();

    // headline: delta vs full bytes at the first (shortest-interval)
    // config — the steady-state shipping comparison
    let pair_ratio = |interval: u64, rate: usize| -> Option<f64> {
        let delta = rows
            .iter()
            .find(|r| r.mode == "delta" && r.sync_interval_ms == interval && r.write_rate == rate)?;
        let full = rows
            .iter()
            .find(|r| r.mode == "full" && r.sync_interval_ms == interval && r.write_rate == rate)?;
        if delta.bytes_shipped == 0 {
            None
        } else {
            Some(full.bytes_shipped as f64 / delta.bytes_shipped as f64)
        }
    };
    let headline = pair_ratio(intervals[0], rates[0]);
    if let Some(ratio) = headline {
        println!(
            "\ndelta shipping moved {ratio:.1}x fewer bytes than full-state shipping at \
             sync={}ms rate={}/s (target >= 5x)",
            intervals[0], rates[0]
        );
    }

    let json = Json::obj(vec![
        (
            "replica",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("mode", Json::Str(r.mode.to_string())),
                            ("sync_interval_ms", Json::Num(r.sync_interval_ms as f64)),
                            ("write_rate", Json::Num(r.write_rate as f64)),
                            ("ships", Json::Num(r.ships as f64)),
                            ("full_ships", Json::Num(r.full_ships as f64)),
                            ("bytes_shipped", Json::Num(r.bytes_shipped as f64)),
                            ("staleness_mean", Json::Num(r.staleness_mean)),
                            ("staleness_p95", Json::Num(r.staleness_p95)),
                            ("staleness_max", Json::Num(r.staleness_max)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("delta_vs_full_bytes_ratio", Json::Num(headline.unwrap_or(0.0))),
    ]);
    match std::fs::write(OUT_PATH, json.to_string_pretty()) {
        Ok(()) => println!("\nwrote {OUT_PATH}"),
        Err(e) => eprintln!("could not write {OUT_PATH}: {e}"),
    }
}
